//! Flight-recorder tracing: a step-clock event log across the serving
//! stack (DESIGN.md §14).
//!
//! The recorder is a bounded ring buffer of typed [`TraceEvent`]s keyed
//! by the logical step clock ([`crate::coordinator::Engine::clock`]),
//! the request id, and — for token events — the Philox `(row, cstep)`
//! coordinate the token was sampled at.  Because the whole stack is
//! deterministic in those coordinates, the trace is not just a debugging
//! aid: it is a *replayable artifact*.  Two runs of the same closed-loop
//! script produce byte-identical event streams, and `repro
//! trace-identity` certifies both that identity and that counters
//! derived from the event log exactly reproduce [`ServingMetrics`] —
//! the metrics layer can no longer silently drift from what the engine
//! actually did.
//!
//! Design constraints, in order:
//!
//! * **Off is free.**  `trace_level = off` (the default) costs one
//!   predictable branch per event site — the same trick the token
//!   stream uses (`Arc::strong_count` in `coordinator/stream.rs`).
//!   Call sites are written `if trace.on() { trace.emit(..) }` (or
//!   `trace.full()` for engine-scoped events), so the off path never
//!   constructs an event.
//! * **Eviction never changes the certificate.**  The ring holds the
//!   most recent [`RING_CAP`] events for export, but the
//!   [FNV-1a](https://en.wikipedia.org/wiki/Fowler%E2%80%93Noll%E2%80%93Vo_hash_function)
//!   digest and the [`DerivedCounters`] are folded incrementally at
//!   emit time over the *canonical JSONL line* of every event — the
//!   digest equals a hash of the full stream no matter how small the
//!   ring is.
//! * **No wall clock anywhere.**  Events carry only logical time (the
//!   step clock) and Philox coordinates, so every field is
//!   deterministic and the digest is replay-stable by construction.
//!   Wall-clock attribution stays in [`ServingMetrics`].
//!
//! Exporters: [`Trace::to_jsonl`] (one canonical JSON object per line)
//! and [`Trace::to_chrome_json`] / [`chrome_export`] — Chrome
//! trace-event JSON loadable in Perfetto (`ui.perfetto.dev`), with one
//! track per request (`tid` = request id) and one process per replica
//! (`pid` = replica index); `ts` is the logical step clock expressed in
//! microseconds, so one engine step renders as 1 µs.
//!
//! [`ServingMetrics`]: crate::metrics::ServingMetrics

use std::collections::VecDeque;

/// Default ring capacity (events) — small enough that an always-on
/// lifecycle trace is bounded memory, large enough to hold the full
/// tail of the repro scripts.  Deployments override it with the
/// `trace_ring_cap` config key (min 64), which reaches
/// [`Trace::with_capacity`] through
/// `EngineConfig::trace_ring_cap`.  Digest and derived counters cover
/// *all* events regardless of capacity (see module docs); only the
/// modeled-time profiler (DESIGN.md §15) needs the ring unevicted.
pub const RING_CAP: usize = 4096;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// How much the recorder captures.  Parsed from the `trace_level`
/// config key.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TraceLevel {
    /// No events; every site costs one branch (the default).
    #[default]
    Off,
    /// Request-scoped lifecycle events: submit/reject, chunk windows,
    /// prefill, per-token decode, spec bursts, swap in/out, preempt,
    /// finish, router dispatch.
    Lifecycle,
    /// Lifecycle plus engine-scoped events: scheduler plan outcomes,
    /// aging promotions, KV alloc/free/CoW deltas, radix attach/evict.
    Full,
}

impl TraceLevel {
    pub fn name(self) -> &'static str {
        match self {
            TraceLevel::Off => "off",
            TraceLevel::Lifecycle => "lifecycle",
            TraceLevel::Full => "full",
        }
    }
}

impl std::str::FromStr for TraceLevel {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "off" => Ok(TraceLevel::Off),
            "lifecycle" => Ok(TraceLevel::Lifecycle),
            "full" => Ok(TraceLevel::Full),
            other => Err(format!(
                "unknown trace_level '{other}' (off | lifecycle | full)"
            )),
        }
    }
}

impl std::fmt::Display for TraceLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One typed trace event.  Fields are named for the canonical JSONL
/// serialization ([`TraceEvent::canonical_line`]) that both exporters
/// and the digest are defined over; `python/tests/sim_trace_bench.py`
/// mirrors the format byte-for-byte.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// Request accepted into the waiting queue.
    Submit { prompt_len: usize, max_new: usize },
    /// Request refused at the front door (admission cause) or rejected
    /// as unschedulable by the open-loop backstop.
    Reject { reason: String },
    /// One chunked-prefill window: `take` prompt tokens consumed,
    /// `prefilled` prompt tokens resident after the window.
    ChunkWindow { take: usize, prefilled: usize },
    /// Whole-prompt (or final-suffix) prefill for one row of a prefill
    /// batch.  `prompt_len` is the FULL prompt length — the quantity
    /// `prefill_tokens` counts — even when only a suffix was computed
    /// (the skipped prefix is a separate [`EventKind::RadixAttach`]).
    Prefill { prompt_len: usize },
    /// First sampled token of a request, with its Philox coordinate.
    FirstToken { row: usize, cstep: u32, token: i32 },
    /// One decode-step token, with its Philox coordinate.
    DecodeToken { row: usize, cstep: u32, token: i32 },
    /// One speculative burst for one row: `drafted` proposed tokens,
    /// `accepted` of them kept, `emitted` total tokens released
    /// (accepted + the corrected/bonus token).  `cstep` is the Philox
    /// step of the burst's first inner pass.
    SpecBurst { row: usize, cstep: u32, drafted: u64, accepted: u64, emitted: u64 },
    /// Blocks swapped out to the host ledger for this request.
    SwapOut { blocks: u64 },
    /// Blocks swapped back in for this request.
    SwapIn { blocks: u64 },
    /// A preemption decision: `kind` is `"swap"` (victim parked in the
    /// swap tier — paired with a [`EventKind::SwapOut`]) or
    /// `"recompute"` (legacy finish-early).  Swap-in park-backs emit
    /// `swap_out` WITHOUT a `preempt`, mirroring the metrics split
    /// between `swapped_out_seqs`/`preempted` and `swap_out_blocks`.
    Preempt { kind: &'static str },
    /// Terminal event: finish reason plus tokens generated.
    Finish { reason: &'static str, tokens: u64 },
    /// Router placement decision.  `affinity_rank` counts replicas
    /// whose probe reported strictly more cached prefix tokens than the
    /// chosen one (0 = the warmest replica won); `spill` is true when a
    /// warmer replica existed but was not chosen.
    Dispatch { policy: &'static str, replica: usize, affinity_rank: usize, spill: bool },
    /// Scheduler plan outcome for one step (full level).
    Plan { outcome: &'static str, batch: usize },
    /// Anti-starvation aging promotions applied this step (full level).
    Promote { count: u64 },
    /// KV blocks allocated this step (full level; per-step delta).
    KvAlloc { blocks: u64 },
    /// KV blocks freed this step (full level; per-step delta).
    KvFree { blocks: u64 },
    /// Copy-on-write block forks this step (full level; per-step
    /// delta).
    KvCow { blocks: u64 },
    /// Prefix-cache tokens attached from the radix tree for one
    /// request whose prefill compute was actually skipped — the
    /// quantity `cached_prefill_tokens` counts.  Request-scoped, so
    /// lifecycle level.
    RadixAttach { tokens: u64 },
    /// Radix-cache blocks evicted this step (full level; per-step
    /// delta).
    RadixEvict { blocks: u64 },
    /// One certified sub-vocabulary decode step whose skip was admitted
    /// (DESIGN.md §16): `active` candidate tiles ran, `skipped` cold
    /// tiles were proven unable to win the Gumbel-argmax.
    /// Request-scoped, so lifecycle level.
    SubvocabSkip { active: u64, skipped: u64 },
    /// One sub-vocabulary decode step where the certificate could not
    /// rule out the excluded tiles and the full-vocabulary pass ran at
    /// the same Philox coordinates.  Request-scoped.
    SubvocabFallback { active: u64, skipped: u64 },
}

impl EventKind {
    /// Event name in the canonical serialization.
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Submit { .. } => "submit",
            EventKind::Reject { .. } => "reject",
            EventKind::ChunkWindow { .. } => "chunk_window",
            EventKind::Prefill { .. } => "prefill",
            EventKind::FirstToken { .. } => "first_token",
            EventKind::DecodeToken { .. } => "decode_token",
            EventKind::SpecBurst { .. } => "spec_burst",
            EventKind::SwapOut { .. } => "swap_out",
            EventKind::SwapIn { .. } => "swap_in",
            EventKind::Preempt { .. } => "preempt",
            EventKind::Finish { .. } => "finish",
            EventKind::Dispatch { .. } => "dispatch",
            EventKind::Plan { .. } => "plan",
            EventKind::Promote { .. } => "promote",
            EventKind::KvAlloc { .. } => "kv_alloc",
            EventKind::KvFree { .. } => "kv_free",
            EventKind::KvCow { .. } => "kv_cow",
            EventKind::RadixAttach { .. } => "radix_attach",
            EventKind::RadixEvict { .. } => "radix_evict",
            EventKind::SubvocabSkip { .. } => "subvocab_skip",
            EventKind::SubvocabFallback { .. } => "subvocab_fallback",
        }
    }

    /// Engine-scoped events only recorded at [`TraceLevel::Full`].
    pub fn full_scope(&self) -> bool {
        matches!(
            self,
            EventKind::Plan { .. }
                | EventKind::Promote { .. }
                | EventKind::KvAlloc { .. }
                | EventKind::KvFree { .. }
                | EventKind::KvCow { .. }
                | EventKind::RadixEvict { .. }
        )
    }

    /// Event-specific fields as a JSON fragment (`"k":v,...`, no
    /// braces), appended to `out`.  Key order is fixed — it defines the
    /// canonical line the digest runs over.
    fn push_args(&self, out: &mut String) {
        use std::fmt::Write as _;
        let _ = match self {
            EventKind::Submit { prompt_len, max_new } => {
                write!(out, "\"prompt_len\":{prompt_len},\"max_new\":{max_new}")
            }
            EventKind::Reject { reason } => {
                write!(out, "\"reason\":{}", json_str(reason))
            }
            EventKind::ChunkWindow { take, prefilled } => {
                write!(out, "\"take\":{take},\"prefilled\":{prefilled}")
            }
            EventKind::Prefill { prompt_len } => {
                write!(out, "\"prompt_len\":{prompt_len}")
            }
            EventKind::FirstToken { row, cstep, token }
            | EventKind::DecodeToken { row, cstep, token } => {
                write!(out, "\"row\":{row},\"cstep\":{cstep},\"token\":{token}")
            }
            EventKind::SpecBurst { row, cstep, drafted, accepted, emitted } => write!(
                out,
                "\"row\":{row},\"cstep\":{cstep},\"drafted\":{drafted},\
                 \"accepted\":{accepted},\"emitted\":{emitted}"
            ),
            EventKind::SwapOut { blocks }
            | EventKind::SwapIn { blocks }
            | EventKind::KvAlloc { blocks }
            | EventKind::KvFree { blocks }
            | EventKind::KvCow { blocks }
            | EventKind::RadixEvict { blocks } => {
                write!(out, "\"blocks\":{blocks}")
            }
            EventKind::Preempt { kind } => {
                write!(out, "\"kind\":{}", json_str(kind))
            }
            EventKind::Finish { reason, tokens } => {
                write!(out, "\"reason\":{},\"tokens\":{tokens}", json_str(reason))
            }
            EventKind::Dispatch { policy, replica, affinity_rank, spill } => write!(
                out,
                "\"policy\":{},\"replica\":{replica},\
                 \"affinity_rank\":{affinity_rank},\"spill\":{spill}",
                json_str(policy)
            ),
            EventKind::Plan { outcome, batch } => {
                write!(out, "\"outcome\":{},\"batch\":{batch}", json_str(outcome))
            }
            EventKind::Promote { count } => write!(out, "\"count\":{count}"),
            EventKind::RadixAttach { tokens } => {
                write!(out, "\"tokens\":{tokens}")
            }
            EventKind::SubvocabSkip { active, skipped }
            | EventKind::SubvocabFallback { active, skipped } => {
                write!(out, "\"active\":{active},\"skipped\":{skipped}")
            }
        };
    }
}

/// One recorded event: monotone emission index, logical step clock,
/// request id (engine-scoped events carry the id of the affected
/// request, or 0 when none applies), and the typed payload.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub seq: u64,
    pub step: u64,
    pub id: u64,
    pub kind: EventKind,
}

impl TraceEvent {
    /// The canonical JSONL serialization — the digest, the JSONL
    /// exporter, and the Python mirror are all defined over exactly
    /// this byte sequence (without a trailing newline).
    pub fn canonical_line(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::with_capacity(96);
        let _ = write!(
            out,
            "{{\"seq\":{},\"step\":{},\"id\":{},\"ev\":\"{}\"",
            self.seq,
            self.step,
            self.id,
            self.kind.name()
        );
        let mut args = String::new();
        self.kind.push_args(&mut args);
        if !args.is_empty() {
            out.push(',');
            out.push_str(&args);
        }
        out.push('}');
        out
    }
}

/// Counters folded incrementally from the event stream — the quantities
/// `repro trace-identity` compares against [`ServingMetrics`]
/// field-for-field (see that module for which metric each one mirrors).
///
/// [`ServingMetrics`]: crate::metrics::ServingMetrics
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DerivedCounters {
    /// `first_token` + `decode_token` + `spec_burst.emitted` — mirrors
    /// `tokens_generated`.
    pub tokens: u64,
    /// Σ `prefill.prompt_len` — mirrors `prefill_tokens` (chunk windows
    /// add nothing: the final-chunk `prefill` row carries the full
    /// prompt length, exactly as the metric is bumped).
    pub prefill_tokens: u64,
    /// Σ `radix_attach.tokens` — mirrors `cached_prefill_tokens`.
    pub cached_prefill_tokens: u64,
    /// `chunk_window` count — mirrors `chunked_prefill_steps`.
    pub chunk_windows: u64,
    /// Σ `swap_out.blocks` — mirrors `swap_out_blocks`.
    pub swap_out_blocks: u64,
    /// Σ `swap_in.blocks` — mirrors `swap_in_blocks`.
    pub swap_in_blocks: u64,
    /// Σ `spec_burst.drafted` — mirrors counter `spec_draft_tokens`.
    pub spec_drafted: u64,
    /// Σ `spec_burst.accepted` — mirrors counter `spec_accepted_tokens`.
    pub spec_accepted: u64,
    /// `preempt` events — mirrors counters `preempted` +
    /// `swapped_out_seqs` (swap-in park-backs emit `swap_out` without a
    /// `preempt`, exactly as the metrics split them).
    pub preemptions: u64,
    /// `finish` events.
    pub finishes: u64,
    /// `reject` events.
    pub rejects: u64,
    /// `dispatch` events.
    pub dispatches: u64,
    /// `subvocab_skip` + `subvocab_fallback` events — mirrors counter
    /// `subvocab_steps`.
    pub subvocab_steps: u64,
    /// `subvocab_fallback` events — mirrors counter
    /// `subvocab_fallbacks`.
    pub subvocab_fallbacks: u64,
}

impl DerivedCounters {
    fn fold(&mut self, kind: &EventKind) {
        match kind {
            EventKind::FirstToken { .. } | EventKind::DecodeToken { .. } => {
                self.tokens += 1;
            }
            EventKind::SpecBurst { drafted, accepted, emitted, .. } => {
                self.tokens += emitted;
                self.spec_drafted += drafted;
                self.spec_accepted += accepted;
            }
            EventKind::Prefill { prompt_len } => {
                self.prefill_tokens += *prompt_len as u64;
            }
            EventKind::ChunkWindow { .. } => self.chunk_windows += 1,
            EventKind::RadixAttach { tokens } => {
                self.cached_prefill_tokens += tokens;
            }
            EventKind::SwapOut { blocks } => self.swap_out_blocks += blocks,
            EventKind::SwapIn { blocks } => self.swap_in_blocks += blocks,
            EventKind::Preempt { .. } => self.preemptions += 1,
            EventKind::Finish { .. } => self.finishes += 1,
            EventKind::Reject { .. } => self.rejects += 1,
            EventKind::Dispatch { .. } => self.dispatches += 1,
            EventKind::SubvocabSkip { .. } => self.subvocab_steps += 1,
            EventKind::SubvocabFallback { .. } => {
                self.subvocab_steps += 1;
                self.subvocab_fallbacks += 1;
            }
            _ => {}
        }
    }
}

/// The flight recorder.  One per engine/replica; the router's dispatch
/// events land in the chosen replica's trace so per-replica streams
/// stay self-contained.
#[derive(Clone, Debug)]
pub struct Trace {
    level: TraceLevel,
    cap: usize,
    ring: VecDeque<TraceEvent>,
    seq: u64,
    digest: u64,
    derived: DerivedCounters,
}

impl Default for Trace {
    fn default() -> Self {
        Self::new(TraceLevel::Off)
    }
}

impl Trace {
    pub fn new(level: TraceLevel) -> Self {
        Self::with_capacity(level, RING_CAP)
    }

    pub fn with_capacity(level: TraceLevel, cap: usize) -> Self {
        Self {
            level,
            cap: cap.max(1),
            ring: VecDeque::new(),
            seq: 0,
            digest: FNV_OFFSET,
            derived: DerivedCounters::default(),
        }
    }

    pub fn level(&self) -> TraceLevel {
        self.level
    }

    /// The one-branch off gate: call sites wrap every emission in
    /// `if trace.on() { .. }` so `trace_level = off` never constructs
    /// an event.
    #[inline(always)]
    pub fn on(&self) -> bool {
        self.level != TraceLevel::Off
    }

    /// Gate for engine-scoped (full-level) event sites.
    #[inline(always)]
    pub fn full(&self) -> bool {
        self.level == TraceLevel::Full
    }

    /// Record one event.  Full-scope events are dropped below
    /// [`TraceLevel::Full`]; everything is dropped at
    /// [`TraceLevel::Off`] (belt and braces — sites gate first).
    pub fn emit(&mut self, step: u64, id: u64, kind: EventKind) {
        if !self.on() || (kind.full_scope() && !self.full()) {
            return;
        }
        let ev = TraceEvent { seq: self.seq, step, id, kind };
        self.seq += 1;
        self.derived.fold(&ev.kind);
        let line = ev.canonical_line();
        for b in line.as_bytes() {
            self.digest = (self.digest ^ u64::from(*b)).wrapping_mul(FNV_PRIME);
        }
        self.digest = (self.digest ^ u64::from(b'\n')).wrapping_mul(FNV_PRIME);
        if self.ring.len() == self.cap {
            self.ring.pop_front();
        }
        self.ring.push_back(ev);
    }

    /// Total events emitted (monotone; ring eviction does not reduce
    /// it).
    pub fn total(&self) -> u64 {
        self.seq
    }

    /// Events currently held in the ring.
    pub fn ring_len(&self) -> usize {
        self.ring.len()
    }

    /// FNV-1a 64 digest of the canonical JSONL stream of *every* event
    /// emitted (newline-terminated lines), independent of ring
    /// eviction.  The replay-identity certificate compares this.
    pub fn digest(&self) -> u64 {
        self.digest
    }

    pub fn derived(&self) -> &DerivedCounters {
        &self.derived
    }

    pub fn events(&self) -> impl Iterator<Item = &TraceEvent> {
        self.ring.iter()
    }

    /// Canonical JSONL of the ring contents (the most recent
    /// [`RING_CAP`] events), one event per line.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for ev in &self.ring {
            out.push_str(&ev.canonical_line());
            out.push('\n');
        }
        out
    }

    /// Chrome trace-event JSON for this trace alone, as replica `pid`.
    /// See [`chrome_export`] for the multi-replica merge.
    pub fn to_chrome_json(&self, pid: usize) -> String {
        chrome_export(&[(pid, self)])
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Merge traces into one Chrome trace-event JSON document: one process
/// (`pid`) per replica, one track (`tid`) per request id, engine-scoped
/// events on `tid` 0.  `ts` is the logical step clock in microseconds
/// (1 step = 1 µs), `dur` = 1, so Perfetto renders each step as a unit
/// slice.  Load at `ui.perfetto.dev` or `chrome://tracing`.
pub fn chrome_export(tracks: &[(usize, &Trace)]) -> String {
    use std::fmt::Write as _;
    let mut out = String::from("{\"traceEvents\":[\n");
    let mut first = true;
    for &(pid, trace) in tracks {
        let _ = write!(
            out,
            "{}{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\
             \"tid\":0,\"args\":{{\"name\":\"replica {pid}\"}}}}",
            if first { "" } else { ",\n" }
        );
        first = false;
        let mut seen: Vec<u64> = Vec::new();
        for ev in trace.events() {
            if !seen.contains(&ev.id) {
                seen.push(ev.id);
                let _ = write!(
                    out,
                    ",\n{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{pid},\
                     \"tid\":{id},\"args\":{{\"name\":\"req {id}\"}}}}",
                    id = ev.id
                );
            }
            let cat = if ev.kind.full_scope() { "engine" } else { "lifecycle" };
            let mut args = String::new();
            ev.kind.push_args(&mut args);
            let _ = write!(
                out,
                ",\n{{\"name\":\"{name}\",\"cat\":\"{cat}\",\"ph\":\"X\",\
                 \"ts\":{ts},\"dur\":1,\"pid\":{pid},\"tid\":{tid},\
                 \"args\":{{{args}}}}}",
                name = ev.kind.name(),
                ts = ev.step,
                tid = ev.id,
            );
        }
    }
    out.push_str("\n],\"displayTimeUnit\":\"ms\"}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events(trace: &mut Trace) {
        trace.emit(1, 7, EventKind::Submit { prompt_len: 5, max_new: 8 });
        trace.emit(2, 7, EventKind::Prefill { prompt_len: 5 });
        trace.emit(2, 7, EventKind::FirstToken { row: 0, cstep: 3, token: 42 });
        trace.emit(3, 7, EventKind::DecodeToken { row: 0, cstep: 4, token: 9 });
        trace.emit(4, 7, EventKind::Finish { reason: "max_tokens", tokens: 2 });
    }

    #[test]
    fn off_records_nothing() {
        let mut t = Trace::new(TraceLevel::Off);
        assert!(!t.on() && !t.full());
        sample_events(&mut t);
        assert_eq!(t.total(), 0);
        assert_eq!(t.digest(), Trace::new(TraceLevel::Off).digest());
        assert_eq!(t.derived(), &DerivedCounters::default());
    }

    #[test]
    fn lifecycle_drops_full_scope_events() {
        let mut t = Trace::new(TraceLevel::Lifecycle);
        assert!(t.on() && !t.full());
        t.emit(1, 0, EventKind::Plan { outcome: "decode", batch: 4 });
        t.emit(1, 0, EventKind::KvAlloc { blocks: 2 });
        assert_eq!(t.total(), 0);
        t.emit(1, 3, EventKind::Submit { prompt_len: 4, max_new: 2 });
        assert_eq!(t.total(), 1);
        let mut f = Trace::new(TraceLevel::Full);
        f.emit(1, 0, EventKind::Plan { outcome: "decode", batch: 4 });
        assert_eq!(f.total(), 1);
    }

    #[test]
    fn digest_is_deterministic_and_order_sensitive() {
        let mut a = Trace::new(TraceLevel::Full);
        let mut b = Trace::new(TraceLevel::Full);
        sample_events(&mut a);
        sample_events(&mut b);
        assert_eq!(a.digest(), b.digest());
        assert_ne!(a.digest(), FNV_OFFSET);
        // Swapping two events changes the digest (seq is hashed).
        let mut c = Trace::new(TraceLevel::Full);
        c.emit(2, 7, EventKind::Prefill { prompt_len: 5 });
        c.emit(1, 7, EventKind::Submit { prompt_len: 5, max_new: 8 });
        c.emit(2, 7, EventKind::FirstToken { row: 0, cstep: 3, token: 42 });
        c.emit(3, 7, EventKind::DecodeToken { row: 0, cstep: 4, token: 9 });
        c.emit(4, 7, EventKind::Finish { reason: "max_tokens", tokens: 2 });
        assert_ne!(a.digest(), c.digest());
    }

    #[test]
    fn digest_matches_fnv_over_the_jsonl_stream() {
        // The incremental digest must equal a one-shot FNV-1a over the
        // concatenated newline-terminated canonical lines — this is the
        // contract the Python mirror implements.
        let mut t = Trace::new(TraceLevel::Lifecycle);
        sample_events(&mut t);
        let mut h = FNV_OFFSET;
        for b in t.to_jsonl().as_bytes() {
            h = (h ^ u64::from(*b)).wrapping_mul(FNV_PRIME);
        }
        assert_eq!(t.digest(), h);
    }

    #[test]
    fn ring_eviction_keeps_digest_and_derived_stable() {
        let mut small = Trace::with_capacity(TraceLevel::Lifecycle, 2);
        let mut big = Trace::with_capacity(TraceLevel::Lifecycle, 1024);
        for step in 0..50u64 {
            let ev = EventKind::DecodeToken {
                row: (step % 4) as usize,
                cstep: step as u32,
                token: step as i32 * 3,
            };
            small.emit(step, 1, ev.clone());
            big.emit(step, 1, ev);
        }
        assert_eq!(small.ring_len(), 2);
        assert_eq!(small.total(), 50);
        assert_eq!(small.digest(), big.digest());
        assert_eq!(small.derived(), big.derived());
        assert_eq!(small.derived().tokens, 50);
    }

    #[test]
    fn derived_counters_fold_per_kind() {
        let mut t = Trace::new(TraceLevel::Full);
        t.emit(1, 1, EventKind::RadixAttach { tokens: 4 });
        t.emit(1, 1, EventKind::ChunkWindow { take: 16, prefilled: 20 });
        t.emit(2, 1, EventKind::ChunkWindow { take: 8, prefilled: 28 });
        t.emit(3, 2, EventKind::RadixAttach { tokens: 2 });
        t.emit(3, 2, EventKind::Prefill { prompt_len: 6 });
        t.emit(3, 2, EventKind::FirstToken { row: 0, cstep: 1, token: 5 });
        t.emit(4, 2, EventKind::SpecBurst {
            row: 0,
            cstep: 2,
            drafted: 3,
            accepted: 2,
            emitted: 3,
        });
        t.emit(5, 1, EventKind::Preempt { kind: "swap" });
        t.emit(5, 1, EventKind::SwapOut { blocks: 4 });
        t.emit(6, 1, EventKind::SwapIn { blocks: 4 });
        t.emit(7, 3, EventKind::Preempt { kind: "recompute" });
        t.emit(8, 4, EventKind::Reject { reason: "kv exhausted".into() });
        t.emit(8, 2, EventKind::Finish { reason: "max_tokens", tokens: 4 });
        t.emit(8, 5, EventKind::Dispatch {
            policy: "prefix_affinity",
            replica: 1,
            affinity_rank: 0,
            spill: false,
        });
        t.emit(9, 2, EventKind::SubvocabSkip { active: 2, skipped: 14 });
        t.emit(9, 2, EventKind::SubvocabFallback { active: 2, skipped: 14 });
        let d = t.derived();
        assert_eq!(d.tokens, 4);
        // Chunk windows contribute nothing here: their row's final-chunk
        // `prefill` event carries the full prompt length.
        assert_eq!(d.prefill_tokens, 6);
        assert_eq!(d.cached_prefill_tokens, 6);
        assert_eq!(d.chunk_windows, 2);
        assert_eq!(d.swap_out_blocks, 4);
        assert_eq!(d.swap_in_blocks, 4);
        assert_eq!(d.spec_drafted, 3);
        assert_eq!(d.spec_accepted, 2);
        assert_eq!(d.preemptions, 2);
        assert_eq!(d.finishes, 1);
        assert_eq!(d.rejects, 1);
        assert_eq!(d.dispatches, 1);
        assert_eq!(d.subvocab_steps, 2);
        assert_eq!(d.subvocab_fallbacks, 1);
    }

    #[test]
    fn canonical_lines_are_stable_json() {
        let ev = TraceEvent {
            seq: 3,
            step: 11,
            id: 9,
            kind: EventKind::SpecBurst {
                row: 1,
                cstep: 17,
                drafted: 4,
                accepted: 2,
                emitted: 3,
            },
        };
        assert_eq!(
            ev.canonical_line(),
            "{\"seq\":3,\"step\":11,\"id\":9,\"ev\":\"spec_burst\",\
             \"row\":1,\"cstep\":17,\"drafted\":4,\"accepted\":2,\
             \"emitted\":3}"
        );
        let rej = TraceEvent {
            seq: 0,
            step: 1,
            id: 2,
            kind: EventKind::Reject { reason: "a \"quoted\" cause".into() },
        };
        assert_eq!(
            rej.canonical_line(),
            "{\"seq\":0,\"step\":1,\"id\":2,\"ev\":\"reject\",\
             \"reason\":\"a \\\"quoted\\\" cause\"}"
        );
    }

    #[test]
    fn chrome_export_names_tracks_and_replicas() {
        let mut a = Trace::new(TraceLevel::Lifecycle);
        sample_events(&mut a);
        let mut b = Trace::new(TraceLevel::Lifecycle);
        b.emit(1, 12, EventKind::Submit { prompt_len: 3, max_new: 1 });
        let doc = chrome_export(&[(0, &a), (1, &b)]);
        assert!(doc.starts_with("{\"traceEvents\":["));
        assert!(doc.contains("\"name\":\"replica 0\""));
        assert!(doc.contains("\"name\":\"replica 1\""));
        assert!(doc.contains("\"name\":\"req 7\""));
        assert!(doc.contains("\"name\":\"req 12\""));
        assert!(doc.contains("\"ph\":\"X\""));
        assert!(doc.contains("\"ts\":3"));
        // Well-formed: every brace closed, document ends with the
        // trailing metadata.
        assert_eq!(doc.matches('{').count(), doc.matches('}').count());
        assert!(doc.trim_end().ends_with("\"displayTimeUnit\":\"ms\"}"));
        // Single-trace wrapper agrees with the merged exporter.
        assert_eq!(a.to_chrome_json(0), chrome_export(&[(0, &a)]));
    }

    #[test]
    fn trace_level_parses() {
        assert_eq!("off".parse::<TraceLevel>().unwrap(), TraceLevel::Off);
        assert_eq!(
            "lifecycle".parse::<TraceLevel>().unwrap(),
            TraceLevel::Lifecycle
        );
        assert_eq!("full".parse::<TraceLevel>().unwrap(), TraceLevel::Full);
        assert!("verbose".parse::<TraceLevel>().is_err());
        assert_eq!(TraceLevel::Full.to_string(), "full");
        assert_eq!(TraceLevel::default(), TraceLevel::Off);
    }
}
