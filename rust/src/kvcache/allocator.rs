//! Block allocator + per-sequence block table (the paged-cache substrate).

use anyhow::{bail, Result};

/// Physical block identifier.
pub type BlockId = u32;

/// Free-list allocator over a fixed pool of refcounted blocks.
#[derive(Debug)]
pub struct BlockAllocator {
    free: Vec<BlockId>,
    refcount: Vec<u32>,
}

impl BlockAllocator {
    pub fn new(num_blocks: usize) -> Self {
        Self {
            // LIFO free list: recently freed blocks are reused first (cache
            // locality on a real device; deterministic here).
            free: (0..num_blocks as BlockId).rev().collect(),
            refcount: vec![0; num_blocks],
        }
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn total_blocks(&self) -> usize {
        self.refcount.len()
    }

    /// Allocate one block (refcount = 1).
    pub fn allocate(&mut self) -> Result<BlockId> {
        let Some(b) = self.free.pop() else {
            bail!("KV cache exhausted: 0 free of {}", self.refcount.len());
        };
        debug_assert_eq!(self.refcount[b as usize], 0);
        self.refcount[b as usize] = 1;
        Ok(b)
    }

    /// Allocate `n` blocks atomically (all or nothing).
    pub fn allocate_many(&mut self, n: usize) -> Result<Vec<BlockId>> {
        if self.free.len() < n {
            bail!(
                "KV cache exhausted: need {n} blocks, {} free of {}",
                self.free.len(),
                self.refcount.len()
            );
        }
        Ok((0..n).map(|_| self.allocate().unwrap()).collect())
    }

    /// Increment a block's refcount (copy-on-write fork).
    pub fn add_ref(&mut self, b: BlockId) -> Result<()> {
        let rc = &mut self.refcount[b as usize];
        if *rc == 0 {
            bail!("add_ref on free block {b}");
        }
        *rc += 1;
        Ok(())
    }

    /// Decrement a block's refcount, returning it to the pool at zero.
    pub fn free(&mut self, b: BlockId) -> Result<()> {
        let rc = &mut self.refcount[b as usize];
        if *rc == 0 {
            bail!("double free of block {b}");
        }
        *rc -= 1;
        if *rc == 0 {
            self.free.push(b);
        }
        Ok(())
    }

    pub fn refcount(&self, b: BlockId) -> u32 {
        self.refcount[b as usize]
    }
}

/// One sequence's ordered block list + logical token length.
#[derive(Clone, Debug)]
pub struct BlockTable {
    block_size: usize,
    blocks: Vec<BlockId>,
    len: usize,
}

impl BlockTable {
    pub fn new(block_size: usize) -> Self {
        Self { block_size, blocks: Vec::new(), len: 0 }
    }

    pub fn push(&mut self, b: BlockId) {
        self.blocks.push(b);
    }

    pub fn blocks(&self) -> &[BlockId] {
        &self.blocks
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Logical token count stored.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub(crate) fn set_len(&mut self, len: usize) {
        debug_assert!(len <= self.blocks.len() * self.block_size);
        self.len = len;
    }

    /// Remove and return the last block (speculative-decode rollback; the
    /// caller owns the refcount bookkeeping and the `len` invariant).
    pub(crate) fn pop(&mut self) -> Option<BlockId> {
        self.blocks.pop()
    }

    /// Map a logical token position to (block, offset) — what a paged
    /// attention kernel would consume.
    pub fn locate(&self, pos: usize) -> Option<(BlockId, usize)> {
        if pos >= self.len {
            return None;
        }
        Some((self.blocks[pos / self.block_size], pos % self.block_size))
    }

    /// Slack capacity in the last block.
    pub fn tail_capacity(&self) -> usize {
        self.blocks.len() * self.block_size - self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifo_reuse() {
        let mut a = BlockAllocator::new(4);
        let b0 = a.allocate().unwrap();
        let b1 = a.allocate().unwrap();
        a.free(b0).unwrap();
        let b2 = a.allocate().unwrap();
        assert_eq!(b0, b2); // most-recently-freed reused first
        assert_ne!(b1, b2);
    }

    #[test]
    fn refcounting() {
        let mut a = BlockAllocator::new(2);
        let b = a.allocate().unwrap();
        a.add_ref(b).unwrap();
        a.free(b).unwrap();
        assert_eq!(a.free_blocks(), 1); // still one ref
        a.free(b).unwrap();
        assert_eq!(a.free_blocks(), 2);
        assert!(a.free(b).is_err()); // double free detected
        assert!(a.add_ref(b).is_err()); // ref on free block detected
    }

    #[test]
    fn allocate_many_is_atomic() {
        let mut a = BlockAllocator::new(3);
        assert!(a.allocate_many(4).is_err());
        assert_eq!(a.free_blocks(), 3); // nothing leaked by the failed call
        let v = a.allocate_many(3).unwrap();
        assert_eq!(v.len(), 3);
        assert_eq!(a.free_blocks(), 0);
    }

    #[test]
    fn locate_maps_positions() {
        let mut t = BlockTable::new(4);
        t.push(7);
        t.push(9);
        t.set_len(6);
        assert_eq!(t.locate(0), Some((7, 0)));
        assert_eq!(t.locate(3), Some((7, 3)));
        assert_eq!(t.locate(4), Some((9, 0)));
        assert_eq!(t.locate(5), Some((9, 1)));
        assert_eq!(t.locate(6), None); // beyond len
        assert_eq!(t.tail_capacity(), 2);
    }
}
