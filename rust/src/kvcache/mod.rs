//! Paged KV-cache management (vLLM-style block allocator).
//!
//! The serving coordinator tracks each sequence's KV footprint in
//! fixed-size *blocks* of token positions, with a free-list allocator,
//! per-sequence block tables, and copy-on-write reference counts (prefix
//! sharing).  This is the scheduler's admission-control currency: a
//! sequence can only be scheduled if its next token has a block to land in.
//!
//! On top of the allocator sits the **automatic prefix cache**
//! (DESIGN.md §10): a [`crate::prefixcache::RadixTree`] maps full-block
//! token prefixes to refcounted block ids, so a request whose prompt
//! repeats an earlier prompt's prefix attaches those blocks copy-on-write
//! ([`KvCacheManager::register_with_prefix`]) instead of recomputing them,
//! and allocation pressure reclaims cached blocks LRU-leaf-first.  Tree
//! refcounts and allocator refcounts move in lockstep:
//!
//! * cached node        ⇒ the cache holds one allocator ref on its block;
//! * attached sequence  ⇒ one allocator ref per attached block (exactly
//!   the [`KvCacheManager::fork`] copy-on-write discipline) plus one tree
//!   ref per attached node, both dropped at [`KvCacheManager::release`];
//! * eviction           ⇒ drops the cache's ref; a block returns to the
//!   free list only when no sequence holds it either.
//!
//! Physical storage note: on real GPUs the block table indexes paged HBM
//! buffers; here the physical KV lives in the dense per-batch cache tensors
//! the AOT decode artifacts carry (see DESIGN.md §2 substitutions), and
//! cached blocks carry their `[L, H, block_size, Dh]` payload in the tree
//! ([`crate::prefixcache::BlockKv`]) — the stand-in for the block's HBM
//! page surviving its sequence.  The *management* layer — allocation,
//! fragmentation, eviction, utilization accounting — is the real
//! vLLM-equivalent machinery and is what the coordinator benches exercise.
//!
//! **Swap tier** (DESIGN.md §12): instead of throwing a preempted
//! sequence's KV away, [`KvCacheManager::swap_out`] moves its *private*
//! blocks to a host-side ledger (capacity [`KvCacheManager::set_swap_capacity`],
//! modeling pinned host memory over PCIe) and frees them device-side;
//! [`KvCacheManager::swap_in`] re-allocates them when pressure clears.
//! Prefix-cache attachments are deliberately NOT swapped: the attached
//! chain stays pinned (tree refs + allocator refs held, `seq_nodes`
//! untouched), so a swap round-trip preserves radix identity by
//! construction — the same nodes serve the same prefixes before, during,
//! and after the swap.  In the dense-KV substitution the physical bytes
//! live in `Sequence.kv` either way; the ledger is the accounting truth
//! the PCIe cost model ([`crate::gpusim::iomodel::PcieModel`]) prices.

pub mod allocator;

pub use allocator::{BlockAllocator, BlockId, BlockTable};

use anyhow::{bail, Result};

use crate::prefixcache::{BlockKv, RadixTree};

/// Configuration of the paged cache.
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    /// Token positions per block (vLLM default 16).
    pub block_size: usize,
    /// Total number of physical blocks available.
    pub num_blocks: usize,
    /// Enable the automatic prefix cache (radix-tree KV reuse across
    /// requests, DESIGN.md §10).
    pub prefix_caching: bool,
}

impl Default for KvCacheConfig {
    fn default() -> Self {
        Self { block_size: 16, num_blocks: 1024, prefix_caching: false }
    }
}

/// Result of a prefix-cache-aware registration: how many prompt tokens
/// were served from the cache, and the physical KV payload of each
/// attached block (chain order) for the engine to restore.
#[derive(Debug, Default)]
pub struct PrefixAttach {
    /// Cached prompt tokens (a multiple of the block size, always
    /// `< prompt.len()` so prefill retains a non-empty suffix to compute
    /// the first-token hidden state from).
    pub cached_tokens: usize,
    /// Physical payload of each attached block, in chain order.
    pub kv: Vec<BlockKv>,
}

/// One prefill batch's admission tally: blocks already promised to
/// earlier candidates of the same batch are reserved against the shared
/// headroom, so a batch of individually admissible prompts can never
/// oversubscribe the pool.  This is THE engine admission rule — the
/// scheduler closure in `Engine::step` and the `repro prefix-identity`
/// simulation both call [`BatchAdmission::admit`], so the exactness
/// certificate always exercises the engine's real admission logic.
#[derive(Debug, Default)]
pub struct BatchAdmission {
    committed: usize,
}

impl BatchAdmission {
    /// Probe (and on success, reserve) admission for one candidate:
    /// charges only the prompt's uncached blocks, plus `extra_tokens` of
    /// decode-burst headroom, against free + reclaimable blocks minus
    /// what earlier candidates of this batch already committed.
    pub fn admit(
        &mut self,
        kv: &KvCacheManager,
        prompt: &[i32],
        extra_tokens: usize,
    ) -> bool {
        let need = kv.prefill_blocks_needed(prompt, extra_tokens);
        let ok = kv.prefill_headroom(prompt) >= self.committed + need;
        if ok {
            self.committed += need;
        }
        ok
    }
}

/// Host-side swap ledger entry for one swapped-out sequence: how many
/// private blocks were freed device-side and the logical token length to
/// restore at swap-in.
#[derive(Clone, Copy, Debug)]
struct SwapEntry {
    blocks: usize,
    len: usize,
}

/// High-level cache manager: per-sequence block tables over one allocator,
/// plus the optional prefix-cache radix tree.
pub struct KvCacheManager {
    config: KvCacheConfig,
    allocator: BlockAllocator,
    tables: std::collections::HashMap<u64, BlockTable>,
    prefix: Option<RadixTree>,
    /// Nodes each live sequence is attached through (for release-time
    /// detach; the inverse of `RadixTree::attach`).
    seq_nodes: std::collections::HashMap<u64, Vec<usize>>,
    evicted_blocks: u64,
    /// Host-side swap ledger: seq id -> freed private blocks + length.
    swapped: std::collections::HashMap<u64, SwapEntry>,
    /// Ledger capacity in blocks (0 = swap tier disabled).
    swap_capacity: usize,
    /// Monotone bookkeeping counters for the flight recorder's per-step
    /// KV delta events (DESIGN.md §14): blocks newly allocated, sequence
    /// refs dropped (release / truncate / swap-out), and copy-on-write
    /// tail forks.  Pure accounting — never consulted by allocation.
    stat_alloc_blocks: u64,
    stat_freed_blocks: u64,
    stat_cow_forks: u64,
}

impl KvCacheManager {
    pub fn new(config: KvCacheConfig) -> Self {
        Self {
            config,
            allocator: BlockAllocator::new(config.num_blocks),
            tables: std::collections::HashMap::new(),
            prefix: config.prefix_caching.then(|| RadixTree::new(config.block_size)),
            seq_nodes: std::collections::HashMap::new(),
            evicted_blocks: 0,
            swapped: std::collections::HashMap::new(),
            swap_capacity: 0,
            stat_alloc_blocks: 0,
            stat_freed_blocks: 0,
            stat_cow_forks: 0,
        }
    }

    /// Monotone count of blocks newly allocated (fresh allocations only —
    /// prefix-cache attach refs are shares, not allocations).
    pub fn stat_alloc_blocks(&self) -> u64 {
        self.stat_alloc_blocks
    }

    /// Monotone count of sequence block refs dropped via release,
    /// truncate rollback, or swap-out.
    pub fn stat_freed_blocks(&self) -> u64 {
        self.stat_freed_blocks
    }

    /// Monotone count of copy-on-write tail forks in [`Self::append_token`].
    pub fn stat_cow_forks(&self) -> u64 {
        self.stat_cow_forks
    }

    pub fn config(&self) -> KvCacheConfig {
        self.config
    }

    /// Blocks needed to hold `tokens` positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.config.block_size)
    }

    /// Cached blocks that allocation pressure could actually return to
    /// the free list right now (unpinned nodes whose block the cache is
    /// the sole holder of — a seq-held block survives its node's
    /// eviction, freeing nothing, so it must not count as headroom).
    fn evictable_blocks(&self) -> usize {
        self.prefix
            .as_ref()
            .map_or(0, |t| t.evictable(|b| self.allocator.refcount(b) == 1))
    }

    /// Can a sequence of `tokens` length be admitted right now?  Counts
    /// evictable prefix-cache blocks as headroom (pressure reclaims them).
    pub fn can_allocate(&self, tokens: usize) -> bool {
        self.allocator.free_blocks() + self.evictable_blocks() >= self.blocks_for(tokens)
    }

    /// Longest cached prefix of `prompt`, in tokens (full blocks only,
    /// capped below the prompt length so a prefill suffix always remains).
    /// Pure probe — no refcounts move, safe for admission planning.
    pub fn cached_prefix_tokens(&self, prompt: &[i32]) -> usize {
        let Some(tree) = self.prefix.as_ref() else { return 0 };
        let cap = prompt.len().saturating_sub(1) / self.config.block_size;
        tree.probe_tokens(prompt, cap)
    }

    /// New blocks a prompt (plus `extra_tokens` of decode-burst headroom)
    /// would need beyond its cached prefix — what prefill admission
    /// charges against the budget (only *uncached* blocks).
    pub fn prefill_blocks_needed(&self, prompt: &[i32], extra_tokens: usize) -> usize {
        let matched = self.cached_prefix_tokens(prompt) / self.config.block_size;
        self.blocks_for((prompt.len() + extra_tokens).max(1)) - matched
    }

    /// Free + reclaimable headroom available to admit `prompt`.  Matched
    /// blocks are excluded from the evictable count so they are never
    /// counted both as "reused" and as "reclaimable" (attaching pins
    /// them).  The scheduler's batch admission subtracts blocks already
    /// committed to earlier candidates of the same batch from this.
    pub fn prefill_headroom(&self, prompt: &[i32]) -> usize {
        let matched = self.cached_prefix_tokens(prompt) / self.config.block_size;
        self.allocator.free_blocks() + self.evictable_blocks().saturating_sub(matched)
    }

    /// Cache-aware admission probe: can a prompt (plus `extra_tokens` of
    /// decode-burst headroom) be admitted right now, charging only its
    /// uncached blocks against the budget?
    pub fn can_allocate_prefill(&self, prompt: &[i32], extra_tokens: usize) -> bool {
        self.prefill_headroom(prompt) >= self.prefill_blocks_needed(prompt, extra_tokens)
    }

    /// Start a prefill batch's admission tally (see [`BatchAdmission`]).
    pub fn batch_admission(&self) -> BatchAdmission {
        BatchAdmission::default()
    }

    /// Evict LRU prefix-cache blocks until at least `n` are free (or
    /// nothing more is evictable).  Returns whether `n` free blocks are
    /// available.  Evicting a node whose block is still held by a live
    /// sequence only drops the cache's ref (the block stays resident for
    /// that sequence) — the loop keeps peeling until the free list
    /// actually covers `n` or the tree runs out of unpinned leaves.
    fn ensure_free(&mut self, n: usize) -> bool {
        while self.allocator.free_blocks() < n {
            let Some(b) = self.prefix.as_mut().and_then(|t| t.evict_lru()) else {
                return false;
            };
            self.allocator
                .free(b)
                .expect("cache-held block must carry the cache's refcount");
            self.evicted_blocks += 1;
        }
        true
    }

    /// Blocks reclaimed from the prefix cache under allocation pressure.
    pub fn evicted_blocks(&self) -> u64 {
        self.evicted_blocks
    }

    /// Live blocks in the prefix cache.
    pub fn prefix_cached_blocks(&self) -> usize {
        self.prefix.as_ref().map_or(0, |t| t.cached_blocks())
    }

    /// Drop every unpinned cached block (ops/testing hook; pressure
    /// eviction does this incrementally).  Returns blocks released.
    pub fn clear_prefix_cache(&mut self) -> usize {
        let mut n = 0;
        while let Some(b) = self.prefix.as_mut().and_then(|t| t.evict_lru()) {
            self.allocator
                .free(b)
                .expect("cache-held block must carry the cache's refcount");
            n += 1;
        }
        n
    }

    /// Register a new sequence with `prompt_tokens` already in the cache.
    pub fn register(&mut self, seq_id: u64, prompt_tokens: usize) -> Result<()> {
        if self.tables.contains_key(&seq_id) {
            bail!("sequence {seq_id} already registered");
        }
        let n = self.blocks_for(prompt_tokens.max(1));
        self.ensure_free(n); // best effort; allocate_many reports exhaustion
        let blocks = self.allocator.allocate_many(n)?;
        self.stat_alloc_blocks += n as u64;
        let mut table = BlockTable::new(self.config.block_size);
        for b in blocks {
            table.push(b);
        }
        table.set_len(prompt_tokens);
        self.tables.insert(seq_id, table);
        Ok(())
    }

    /// Register a new sequence, attaching the longest cached prefix of
    /// `prompt` copy-on-write (the [`Self::fork`] refcount machinery) and
    /// allocating blocks only for the uncached remainder.  Returns how
    /// many prompt tokens the cache served and their physical payloads.
    /// With prefix caching disabled this is exactly [`Self::register`].
    pub fn register_with_prefix(&mut self, seq_id: u64, prompt: &[i32]) -> Result<PrefixAttach> {
        if self.tables.contains_key(&seq_id) {
            bail!("sequence {seq_id} already registered");
        }
        if self.prefix.is_none() {
            self.register(seq_id, prompt.len())?;
            return Ok(PrefixAttach::default());
        }
        let bs = self.config.block_size;
        // Cap below the prompt length: prefill must keep >= 1 suffix token
        // to produce the hidden state the first output token samples from.
        let cap_blocks = prompt.len().saturating_sub(1) / bs;
        // Attach FIRST: the tree refs pin the matched chain against the
        // eviction pass below.
        let nodes = self.prefix.as_mut().unwrap().attach(prompt, cap_blocks);
        let matched = nodes.len();
        let needed = self.blocks_for(prompt.len().max(1)) - matched;
        if !self.ensure_free(needed) {
            self.prefix.as_mut().unwrap().detach(&nodes);
            bail!(
                "KV cache exhausted: sequence {seq_id} needs {needed} new \
                 blocks, {} free",
                self.allocator.free_blocks()
            );
        }
        let mut table = BlockTable::new(bs);
        let mut kv = Vec::with_capacity(matched);
        for &n in &nodes {
            let b = self.prefix.as_ref().unwrap().node_block(n);
            self.allocator.add_ref(b)?;
            table.push(b);
            kv.push(self.prefix.as_ref().unwrap().node_kv(n).clone());
        }
        for b in self.allocator.allocate_many(needed)? {
            table.push(b);
        }
        self.stat_alloc_blocks += needed as u64;
        table.set_len(prompt.len().max(1));
        self.tables.insert(seq_id, table);
        if !nodes.is_empty() {
            self.seq_nodes.insert(seq_id, nodes);
        }
        Ok(PrefixAttach { cached_tokens: matched * bs, kv })
    }

    /// Publish a freshly prefilled prompt's full blocks into the prefix
    /// cache; `payload(j)` supplies block `j`'s physical KV and runs only
    /// for blocks not already cached.  The cache takes one allocator ref
    /// per newly inserted block (released at eviction).  Returns how many
    /// blocks were newly cached.  No-op with prefix caching disabled.
    pub fn insert_prefix(
        &mut self,
        seq_id: u64,
        prompt: &[i32],
        payload: impl FnMut(usize) -> BlockKv,
    ) -> Result<usize> {
        let Some(tree) = self.prefix.as_mut() else { return Ok(0) };
        let Some(table) = self.tables.get(&seq_id) else {
            bail!("sequence {seq_id} not registered");
        };
        let new_blocks = tree.insert(prompt, table.blocks(), payload);
        let n = new_blocks.len();
        for b in new_blocks {
            self.allocator.add_ref(b)?;
        }
        Ok(n)
    }

    /// Extend a sequence by one generated token, allocating a block at the
    /// block boundary.  Returns false (and changes nothing) if the pool is
    /// exhausted — the scheduler's signal to preempt.
    ///
    /// Copy-on-write: writing into a *shared* tail block (refcount > 1 via
    /// [`Self::fork`] or a prefix-cache attachment, e.g. after a
    /// spec-decode [`Self::truncate`] rollback landed mid-block) would
    /// corrupt the sibling's token positions, so the shared tail is first
    /// replaced by a private copy — one fresh block, sibling's refcount
    /// dropped by one, siblings untouched.  (In the dense-KV substitution
    /// the bytes live per-sequence, so the "copy" is pure accounting.)
    pub fn append_token(&mut self, seq_id: u64) -> Result<bool> {
        let (len, num_blocks, tail) = {
            let Some(table) = self.tables.get(&seq_id) else {
                bail!("sequence {seq_id} not registered");
            };
            (table.len(), table.num_blocks(), table.blocks().last().copied())
        };
        if len == num_blocks * self.config.block_size {
            // Block boundary: grow the table by one fresh block.
            if !self.ensure_free(1) {
                return Ok(false);
            }
            let b = self.allocator.allocate()?;
            self.stat_alloc_blocks += 1;
            let table = self.tables.get_mut(&seq_id).expect("checked above");
            table.push(b);
            table.set_len(len + 1);
        } else {
            let tail = tail.expect("registered sequences have >= 1 block");
            if self.allocator.refcount(tail) > 1 {
                // Copy-on-write into the shared tail.
                if !self.ensure_free(1) {
                    return Ok(false);
                }
                let nb = self.allocator.allocate()?;
                self.stat_alloc_blocks += 1;
                self.stat_cow_forks += 1;
                self.allocator.free(tail)?; // drop our ref on the shared block
                let table =
                    self.tables.get_mut(&seq_id).expect("checked above");
                table.pop();
                table.push(nb);
                table.set_len(len + 1);
            } else {
                let table =
                    self.tables.get_mut(&seq_id).expect("checked above");
                table.set_len(len + 1);
            }
        }
        Ok(true)
    }

    /// Roll a sequence back to `new_len` tokens, releasing whole blocks
    /// past the boundary — speculative decode's rejection rollback
    /// (DESIGN.md §9): draft positions are reserved optimistically via
    /// [`Self::extend`], then truncated away when the verifier rejects.
    /// `new_len` must stay in `1..=len` (a live sequence never shrinks to
    /// zero tokens).  Popped blocks only *drop this sequence's ref* — a
    /// tail shared via [`Self::fork`] or a prefix attach stays alive for
    /// its other holders, and a later [`Self::append_token`] into a still-
    /// shared tail copies-on-write instead of corrupting the sibling.
    pub fn truncate(&mut self, seq_id: u64, new_len: usize) -> Result<()> {
        let Some(table) = self.tables.get_mut(&seq_id) else {
            bail!("sequence {seq_id} not registered");
        };
        if new_len == 0 || new_len > table.len() {
            bail!(
                "truncate({seq_id}) to {new_len} outside 1..={}",
                table.len()
            );
        }
        let keep = new_len.div_ceil(self.config.block_size);
        while table.num_blocks() > keep {
            let b = table.pop().expect("num_blocks > keep >= 1");
            self.allocator.free(b)?;
            self.stat_freed_blocks += 1;
        }
        table.set_len(new_len);
        Ok(())
    }

    /// Optimistically extend a sequence by up to `n` tokens, stopping
    /// early when the pool runs dry; returns how many tokens were
    /// granted.  Speculative decode reserves its draft positions this
    /// way, then [`Self::truncate`]s back to the verified length — a
    /// partially granted burst just means a shorter draft this step, not
    /// a failure.
    pub fn extend(&mut self, seq_id: u64, n: usize) -> Result<usize> {
        for granted in 0..n {
            if !self.append_token(seq_id)? {
                return Ok(granted);
            }
        }
        Ok(n)
    }

    /// Release all blocks of a finished/preempted sequence (and its
    /// prefix-cache attachments and any pending swap-ledger entry).
    /// Aborting a swapped-out sequence lands here: the resident stub (the
    /// pinned attached chain) frees, the attachments detach, and the
    /// host-side entry vanishes — ledger and pool both balance.
    pub fn release(&mut self, seq_id: u64) -> Result<()> {
        let Some(table) = self.tables.remove(&seq_id) else {
            bail!("sequence {seq_id} not registered");
        };
        self.swapped.remove(&seq_id);
        if let Some(nodes) = self.seq_nodes.remove(&seq_id) {
            if let Some(tree) = self.prefix.as_mut() {
                tree.detach(&nodes);
            }
        }
        self.stat_freed_blocks += table.num_blocks() as u64;
        for b in table.blocks() {
            self.allocator.free(*b)?;
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Swap tier (DESIGN.md §12)
    // -----------------------------------------------------------------

    /// Set the host-side swap ledger capacity in blocks (0 disables the
    /// swap tier).  Models a pinned host buffer sized by the operator.
    pub fn set_swap_capacity(&mut self, blocks: usize) {
        self.swap_capacity = blocks;
    }

    pub fn swap_capacity(&self) -> usize {
        self.swap_capacity
    }

    /// Blocks currently parked in the host-side ledger.
    pub fn swapped_blocks(&self) -> usize {
        self.swapped.values().map(|e| e.blocks).sum()
    }

    /// Sequences currently swapped out.
    pub fn swapped_sequences(&self) -> usize {
        self.swapped.len()
    }

    pub fn is_swapped(&self, seq_id: u64) -> bool {
        self.swapped.contains_key(&seq_id)
    }

    /// The prefix-cache node ids `seq_id` is attached through (chain
    /// order) — the radix-identity audit hook: a swap round-trip must
    /// leave this list (and the nodes' blocks) bit-identical.
    pub fn seq_attached_nodes(&self, seq_id: u64) -> Vec<usize> {
        self.seq_nodes.get(&seq_id).cloned().unwrap_or_default()
    }

    /// Swap a preempted sequence's *private* blocks out to the host-side
    /// ledger, freeing them device-side.  The first `attached` table
    /// entries (its prefix-cache chain) stay resident and pinned — tree
    /// refs, allocator refs, and `seq_nodes` are untouched, which is what
    /// preserves radix identity across the round-trip.  Returns
    /// `Ok(None)` when the ledger lacks capacity (the caller falls back
    /// to finish-and-recompute), `Ok(Some(n))` with the number of blocks
    /// parked on success.
    pub fn swap_out(&mut self, seq_id: u64) -> Result<Option<usize>> {
        if self.swapped.contains_key(&seq_id) {
            bail!("sequence {seq_id} is already swapped out");
        }
        let attached = self.seq_nodes.get(&seq_id).map_or(0, |n| n.len());
        let Some(table) = self.tables.get(&seq_id) else {
            bail!("sequence {seq_id} not registered");
        };
        let private = table.num_blocks() - attached;
        if self.swapped_blocks() + private > self.swap_capacity {
            return Ok(None);
        }
        let len = table.len();
        let table = self.tables.get_mut(&seq_id).expect("checked above");
        for _ in 0..private {
            let b = table.pop().expect("num_blocks > attached");
            self.allocator.free(b)?;
        }
        self.stat_freed_blocks += private as u64;
        if private > 0 {
            // Invariant num_blocks == ceil(len / bs) guarantees
            // len > attached * bs whenever a private block existed.
            table.set_len(attached * self.config.block_size);
        }
        self.swapped.insert(seq_id, SwapEntry { blocks: private, len });
        Ok(Some(private))
    }

    /// Bring a swapped-out sequence back: re-allocate its private blocks
    /// (evicting cache LRU leaves under pressure) and restore its logical
    /// length.  `Ok(None)` on transient exhaustion — the sequence stays
    /// in the ledger and the caller retries later; `Ok(Some(n))` with the
    /// blocks restored on success.
    pub fn swap_in(&mut self, seq_id: u64) -> Result<Option<usize>> {
        let Some(entry) = self.swapped.get(&seq_id).copied() else {
            bail!("sequence {seq_id} is not swapped out");
        };
        if !self.tables.contains_key(&seq_id) {
            bail!("sequence {seq_id} not registered");
        }
        if !self.ensure_free(entry.blocks) {
            return Ok(None);
        }
        let blocks = self.allocator.allocate_many(entry.blocks)?;
        self.stat_alloc_blocks += entry.blocks as u64;
        let table = self.tables.get_mut(&seq_id).expect("checked above");
        for b in blocks {
            table.push(b);
        }
        table.set_len(entry.len);
        self.swapped.remove(&seq_id);
        Ok(Some(entry.blocks))
    }

    /// Fork a sequence sharing all current blocks copy-on-write (used for
    /// beam/parallel sampling; blocks are refcounted, not copied).
    pub fn fork(&mut self, parent: u64, child: u64) -> Result<()> {
        if self.tables.contains_key(&child) {
            bail!("sequence {child} already registered");
        }
        let Some(table) = self.tables.get(&parent) else {
            bail!("parent {parent} not registered");
        };
        let cloned = table.clone();
        for b in cloned.blocks() {
            self.allocator.add_ref(*b)?;
        }
        self.tables.insert(child, cloned);
        Ok(())
    }

    pub fn table(&self, seq_id: u64) -> Option<&BlockTable> {
        self.tables.get(&seq_id)
    }

    pub fn num_sequences(&self) -> usize {
        self.tables.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.allocator.free_blocks()
    }

    /// Physical pool size.
    pub fn total_blocks(&self) -> usize {
        self.config.num_blocks
    }

    /// Pool-balance diagnostic: blocks neither free nor resident in the
    /// prefix cache.  While sequences are live this counts their private
    /// blocks; once every sequence has been released or aborted it must
    /// be 0 — the zero-leak invariant the abort test suites assert (a
    /// nonzero value at quiescence means a release path dropped a ref or
    /// the cache and allocator refcounts fell out of lockstep).
    pub fn unaccounted_blocks(&self) -> usize {
        self.config.num_blocks
            - self.allocator.free_blocks()
            - self.prefix_cached_blocks()
    }

    /// Sequence-attachment refs currently held on prefix-cache nodes
    /// (see [`crate::prefixcache::RadixTree::attached_refs`]); 0 whenever
    /// no sequence is attached — aborts must drop theirs.
    pub fn prefix_attached_refs(&self) -> usize {
        self.prefix.as_ref().map_or(0, |t| t.attached_refs())
    }

    /// Fraction of physical blocks in use.
    pub fn utilization(&self) -> f64 {
        1.0 - self.allocator.free_blocks() as f64 / self.config.num_blocks as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil;

    fn mgr(blocks: usize) -> KvCacheManager {
        KvCacheManager::new(KvCacheConfig {
            block_size: 4,
            num_blocks: blocks,
            prefix_caching: false,
        })
    }

    /// Manager with the prefix cache ON (block_size 4).
    fn pmgr(blocks: usize) -> KvCacheManager {
        KvCacheManager::new(KvCacheConfig {
            block_size: 4,
            num_blocks: blocks,
            prefix_caching: true,
        })
    }

    #[test]
    fn register_and_release_roundtrip() {
        let mut m = mgr(16);
        m.register(1, 10).unwrap(); // 3 blocks of 4
        assert_eq!(m.free_blocks(), 13);
        assert_eq!(m.table(1).unwrap().num_blocks(), 3);
        m.release(1).unwrap();
        assert_eq!(m.free_blocks(), 16);
        assert!(m.release(1).is_err());
    }

    #[test]
    fn append_allocates_at_boundary() {
        let mut m = mgr(16);
        m.register(1, 4).unwrap(); // exactly one block
        assert_eq!(m.table(1).unwrap().num_blocks(), 1);
        assert!(m.append_token(1).unwrap()); // needs block 2
        assert_eq!(m.table(1).unwrap().num_blocks(), 2);
        for _ in 0..3 {
            assert!(m.append_token(1).unwrap()); // fills block 2
        }
        assert_eq!(m.table(1).unwrap().num_blocks(), 2);
        assert!(m.append_token(1).unwrap());
        assert_eq!(m.table(1).unwrap().num_blocks(), 3);
    }

    #[test]
    fn exhaustion_signals_preemption_without_corruption() {
        let mut m = mgr(2);
        m.register(1, 8).unwrap(); // both blocks
        assert_eq!(m.free_blocks(), 0);
        let len_before = m.table(1).unwrap().len();
        assert!(!m.append_token(1).unwrap()); // no room
        assert_eq!(m.table(1).unwrap().len(), len_before);
        m.release(1).unwrap();
        assert_eq!(m.free_blocks(), 2);
    }

    #[test]
    fn fork_shares_blocks_cow() {
        let mut m = mgr(8);
        m.register(1, 8).unwrap(); // 2 blocks
        m.fork(1, 2).unwrap();
        assert_eq!(m.free_blocks(), 6); // shared, not copied
        m.release(1).unwrap();
        assert_eq!(m.free_blocks(), 6); // still referenced by child
        m.release(2).unwrap();
        assert_eq!(m.free_blocks(), 8);
    }

    #[test]
    fn utilization_tracks_usage() {
        let mut m = mgr(10);
        assert_eq!(m.utilization(), 0.0);
        m.register(1, 20).unwrap(); // 5 blocks
        assert!((m.utilization() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn truncate_releases_whole_blocks_past_the_boundary() {
        let mut m = mgr(16); // block_size = 4
        m.register(1, 10).unwrap(); // 3 blocks
        assert_eq!(m.free_blocks(), 13);
        // Shrinking within the same block frees nothing.
        m.truncate(1, 9).unwrap();
        assert_eq!(m.free_blocks(), 13);
        assert_eq!(m.table(1).unwrap().len(), 9);
        // Crossing block boundaries frees the tail blocks.
        m.truncate(1, 4).unwrap();
        assert_eq!(m.free_blocks(), 15);
        assert_eq!(m.table(1).unwrap().num_blocks(), 1);
        m.truncate(1, 1).unwrap();
        assert_eq!(m.table(1).unwrap().num_blocks(), 1);
        // Errors: growth, zero length, unknown sequence.
        assert!(m.truncate(1, 2).is_err());
        assert!(m.truncate(1, 0).is_err());
        assert!(m.truncate(99, 1).is_err());
        m.release(1).unwrap();
        assert_eq!(m.free_blocks(), 16);
    }

    #[test]
    fn extend_then_truncate_is_the_spec_decode_reservation_protocol() {
        let mut m = mgr(4); // 16 token capacity
        m.register(1, 4).unwrap(); // 1 block full
        // Reserve a K=6 draft burst: grows to 10 tokens / 3 blocks.
        assert_eq!(m.extend(1, 6).unwrap(), 6);
        assert_eq!(m.table(1).unwrap().len(), 10);
        assert_eq!(m.table(1).unwrap().num_blocks(), 3);
        // Verifier accepted 1 of 6: roll back to 5 tokens.
        m.truncate(1, 5).unwrap();
        assert_eq!(m.table(1).unwrap().len(), 5);
        assert_eq!(m.table(1).unwrap().num_blocks(), 2);
        assert_eq!(m.free_blocks(), 2);
        m.release(1).unwrap();
        assert_eq!(m.free_blocks(), 4);
    }

    #[test]
    fn extend_grants_partially_when_the_pool_runs_dry() {
        let mut m = mgr(2); // 8 token capacity
        m.register(1, 6).unwrap(); // 2 blocks, 2 slack slots
        assert_eq!(m.extend(1, 5).unwrap(), 2); // only the slack fits
        assert_eq!(m.table(1).unwrap().len(), 8);
        // A zero grant is fine too — and changes nothing.
        assert_eq!(m.extend(1, 3).unwrap(), 0);
        assert_eq!(m.table(1).unwrap().len(), 8);
        assert!(m.extend(99, 1).is_err());
    }

    #[test]
    fn truncate_respects_copy_on_write_refcounts() {
        let mut m = mgr(8);
        m.register(1, 8).unwrap(); // 2 blocks
        m.fork(1, 2).unwrap(); // shares both blocks
        assert_eq!(m.free_blocks(), 6);
        // Parent rolls back past a shared block: the block stays alive for
        // the child (refcount), nothing returns to the pool yet.
        m.truncate(1, 2).unwrap();
        assert_eq!(m.free_blocks(), 6);
        m.release(2).unwrap();
        assert_eq!(m.free_blocks(), 7); // child's refs gone, tail block freed
        m.release(1).unwrap();
        assert_eq!(m.free_blocks(), 8);
    }

    #[test]
    fn append_into_shared_tail_copies_on_write() {
        // Regression (spec-decode rollback vs fork siblings): parent and
        // child share a partially filled tail block; the parent rolls back
        // mid-block and then appends.  Pre-fix, the append wrote into the
        // SHARED block — silently claiming slots that belong to the child.
        // Post-fix the parent gets a private tail copy first.
        let mut m = mgr(16);
        m.register(1, 10).unwrap(); // 3 blocks, tail holds 2/4 slots
        m.fork(1, 2).unwrap(); // all 3 blocks shared (refcount 2)
        assert_eq!(m.free_blocks(), 13);
        let shared_tail = *m.table(1).unwrap().blocks().last().unwrap();
        // Spec-decode style rollback across into the shared tail...
        m.truncate(1, 9).unwrap();
        // ...then an accepted token lands: must NOT write into the shared
        // block.
        assert!(m.append_token(1).unwrap());
        let new_tail = *m.table(1).unwrap().blocks().last().unwrap();
        assert_ne!(new_tail, shared_tail, "append corrupted the shared tail");
        // The child still owns its original table, untouched.
        assert_eq!(
            *m.table(2).unwrap().blocks().last().unwrap(),
            shared_tail
        );
        assert_eq!(m.table(2).unwrap().len(), 10);
        // Accounting: one fresh block allocated, the shared tail's refcount
        // dropped to the child's single ref.
        assert_eq!(m.free_blocks(), 12);
        // Further appends stay in the (now private) copied tail.
        assert!(m.append_token(1).unwrap());
        assert_eq!(m.table(1).unwrap().num_blocks(), 3);
        assert_eq!(m.free_blocks(), 12);
        // Everything releases cleanly — no leaks, no double frees.
        m.release(1).unwrap();
        m.release(2).unwrap();
        assert_eq!(m.free_blocks(), 16);
    }

    #[test]
    fn register_with_prefix_reuses_cached_blocks() {
        let mut m = pmgr(16);
        let prompt: Vec<i32> = (0..10).collect(); // 2 full blocks + tail
        // Miss: plain registration path, then publish the prefix.
        let a = m.register_with_prefix(1, &prompt).unwrap();
        assert_eq!(a.cached_tokens, 0);
        assert_eq!(m.free_blocks(), 13);
        let inserted = m
            .insert_prefix(1, &prompt, |j| BlockKv {
                k: vec![j as f32],
                v: vec![j as f32 + 0.5],
            })
            .unwrap();
        assert_eq!(inserted, 2); // only the 2 full blocks
        assert_eq!(m.prefix_cached_blocks(), 2);
        m.release(1).unwrap();
        // Cache retains its 2 blocks past the sequence's lifetime.
        assert_eq!(m.free_blocks(), 14);
        // Hit: same prompt attaches both cached blocks, allocates 1.
        let a = m.register_with_prefix(2, &prompt).unwrap();
        assert_eq!(a.cached_tokens, 8);
        assert_eq!(a.kv.len(), 2);
        assert_eq!(a.kv[1].k, vec![1.0]); // payload round-trips
        assert_eq!(m.free_blocks(), 13);
        assert_eq!(m.table(2).unwrap().num_blocks(), 3);
        assert_eq!(m.table(2).unwrap().len(), 10);
        m.release(2).unwrap();
        assert_eq!(m.free_blocks(), 14);
        // Dropping the cache returns the pool to pristine.
        assert_eq!(m.clear_prefix_cache(), 2);
        assert_eq!(m.free_blocks(), 16);
    }

    #[test]
    fn cached_prefix_is_capped_below_the_prompt_length() {
        // An exactly-2-block prompt caches 2 blocks but a repeat attaches
        // only 1: prefill must keep a non-empty suffix.
        let mut m = pmgr(16);
        let prompt: Vec<i32> = (100..108).collect(); // exactly 2 blocks
        m.register_with_prefix(1, &prompt).unwrap();
        m.insert_prefix(1, &prompt, |_| BlockKv::default()).unwrap();
        assert_eq!(m.prefix_cached_blocks(), 2);
        assert_eq!(m.cached_prefix_tokens(&prompt), 4); // capped at len-1
        let a = m.register_with_prefix(2, &prompt).unwrap();
        assert_eq!(a.cached_tokens, 4);
        m.release(1).unwrap();
        m.release(2).unwrap();
    }

    #[test]
    fn append_into_prefix_shared_block_copies_on_write() {
        // A sequence attached to a cached block truncates into it and then
        // appends: copy-on-write must preserve the cached block for future
        // hits.
        let mut m = pmgr(16);
        let prompt: Vec<i32> = (0..8).collect();
        m.register_with_prefix(1, &prompt).unwrap();
        m.insert_prefix(1, &prompt, |_| BlockKv::default()).unwrap();
        m.release(1).unwrap();
        let a = m.register_with_prefix(2, &prompt).unwrap();
        assert_eq!(a.cached_tokens, 4); // 1 attached block + 1 fresh
        let cached_block = m.table(2).unwrap().blocks()[0];
        m.truncate(2, 3).unwrap(); // tail = the SHARED cached block
        assert!(m.append_token(2).unwrap());
        assert_ne!(m.table(2).unwrap().blocks()[0], cached_block);
        // The cache still serves the prefix to a third sequence.
        assert_eq!(m.cached_prefix_tokens(&prompt), 4);
        let a3 = m.register_with_prefix(3, &prompt).unwrap();
        assert_eq!(a3.cached_tokens, 4);
        m.release(2).unwrap();
        m.release(3).unwrap();
        m.clear_prefix_cache();
        assert_eq!(m.free_blocks(), 16);
    }

    #[test]
    fn allocation_pressure_evicts_lru_cached_blocks() {
        let mut m = pmgr(4); // tiny pool
        let p1: Vec<i32> = (0..8).collect();
        m.register_with_prefix(1, &p1).unwrap();
        m.insert_prefix(1, &p1, |_| BlockKv::default()).unwrap();
        m.release(1).unwrap();
        assert_eq!(m.free_blocks(), 2);
        assert_eq!(m.prefix_cached_blocks(), 2);
        // A 12-token stranger needs 3 blocks: pressure evicts the LRU leaf.
        assert!(m.can_allocate(12));
        m.register(2, 12).unwrap();
        assert_eq!(m.evicted_blocks(), 1);
        assert_eq!(m.prefix_cached_blocks(), 1);
        assert_eq!(m.free_blocks(), 0);
        m.release(2).unwrap();
        m.clear_prefix_cache();
        assert_eq!(m.free_blocks(), 4);
    }

    #[test]
    fn attached_chains_survive_allocation_pressure() {
        let mut m = pmgr(4);
        let p1: Vec<i32> = (0..8).collect();
        m.register_with_prefix(1, &p1).unwrap();
        m.insert_prefix(1, &p1, |_| BlockKv::default()).unwrap();
        m.release(1).unwrap();
        // Re-attach: the chain head is pinned (refs > 0) while seq 2 lives.
        let a = m.register_with_prefix(2, &p1).unwrap();
        assert_eq!(a.cached_tokens, 4);
        // Pool: seq 2 holds the attached block + 1 fresh, the cache leaf
        // holds 1 more, 1 free.  An 8-token stranger (2 blocks) proceeds by
        // evicting the unpinned leaf; the attached chain head must survive.
        let stranger: Vec<i32> = (50..58).collect();
        assert!(m.can_allocate_prefill(&stranger, 0));
        m.register_with_prefix(3, &stranger).unwrap();
        assert_eq!(m.evicted_blocks(), 1);
        // The pinned (attached) node survived.
        assert_eq!(m.cached_prefix_tokens(&p1), 4);
        m.release(2).unwrap();
        m.release(3).unwrap();
        m.clear_prefix_cache();
        assert_eq!(m.free_blocks(), 4);
    }

    #[test]
    fn can_allocate_prefill_charges_only_uncached_tokens() {
        let mut m = pmgr(4);
        let p1: Vec<i32> = (0..8).collect();
        m.register_with_prefix(1, &p1).unwrap();
        m.insert_prefix(1, &p1, |_| BlockKv::default()).unwrap();
        m.release(1).unwrap();
        // free = 2, cached = 2 (evictable).
        // A 16-token prompt extending the cached prefix: 2 of 4 blocks are
        // cached, 2 fresh needed, 2 free => admissible WITHOUT eviction.
        let extending: Vec<i32> = (0..16).collect();
        assert!(m.can_allocate_prefill(&extending, 0));
        // A 16-token stranger needs all 4 via eviction: admissible too.
        let stranger: Vec<i32> = (90..106).collect();
        assert!(m.can_allocate_prefill(&stranger, 0));
        // 20 tokens (5 blocks) exceed the whole pool: not admissible, and
        // burst headroom tightens the same probe.
        let big: Vec<i32> = (90..110).collect();
        assert!(!m.can_allocate_prefill(&big, 0));
        assert!(!m.can_allocate_prefill(&stranger, 4)); // 16 + 4 => 5 blocks
        // The cache-blind probe would have rejected the extending prompt's
        // total footprint only if it ignored reuse — check the charge is
        // really suffix-only: fill the 2 free blocks, then the extending
        // prompt (needs 2 fresh) must fail while a fully-cached-prefix
        // 9-token prompt (needs 1 fresh... via eviction) still passes.
        m.register(7, 8).unwrap(); // takes the 2 free blocks
        assert!(!m.can_allocate_prefill(&extending, 0));
        m.release(7).unwrap();
    }

    #[test]
    fn prop_extend_truncate_never_leaks() {
        testutil::cases(64, 0x5DEC, |g| {
            let mut m = mgr(32);
            m.register(0, g.usize_in(1, 12)).unwrap();
            for _ in 0..g.usize_in(1, 40) {
                if g.bool(0.5) {
                    let _ = m.extend(0, g.usize_in(0, 9)).unwrap();
                } else {
                    let len = m.table(0).unwrap().len();
                    let target = g.usize_in(1, len);
                    m.truncate(0, target).unwrap();
                }
                // Invariant: blocks exactly cover the logical length.
                let t = m.table(0).unwrap();
                assert!(t.num_blocks() * 4 >= t.len());
                assert!((t.num_blocks() - 1) * 4 < t.len().max(1));
            }
            m.release(0).unwrap();
            assert_eq!(m.free_blocks(), 32, "leaked blocks");
        });
    }

    #[test]
    fn prop_alloc_free_never_leaks() {
        testutil::cases(64, 0xCAFE, |g| {
            let mut m = mgr(32);
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize_in(1, 60) {
                if live.is_empty() || g.bool(0.5) {
                    let toks = g.usize_in(1, 24);
                    if m.can_allocate(toks) {
                        m.register(next_id, toks).unwrap();
                        live.push(next_id);
                        next_id += 1;
                    }
                } else if g.bool(0.3) {
                    let idx = g.usize_in(0, live.len() - 1);
                    let id = live.swap_remove(idx);
                    m.release(id).unwrap();
                } else {
                    let idx = g.usize_in(0, live.len() - 1);
                    let _ = m.append_token(live[idx]).unwrap();
                }
            }
            for id in live {
                m.release(id).unwrap();
            }
            assert_eq!(m.free_blocks(), 32, "leaked blocks");
            assert_eq!(m.num_sequences(), 0);
        });
    }

    #[test]
    fn prop_prefix_cache_refcounts_stay_in_lockstep() {
        // Random interleaving of prefix-aware registrations (from a small
        // prompt pool, so hits are common), insertions, appends, truncates,
        // forks, and releases — then: releasing every sequence and draining
        // the cache must return the pool to pristine, and at every step
        // free + cached <= total.
        testutil::cases(48, 0xCACE, |g| {
            let mut m = pmgr(32);
            let prompts: Vec<Vec<i32>> = (0..4)
                .map(|p| {
                    let len = 5 + 4 * p; // 5, 9, 13, 17 tokens
                    (0..len as i32).map(|i| i + 100 * p as i32).collect()
                })
                .collect();
            let mut live: Vec<(u64, usize)> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize_in(1, 50) {
                let roll = g.f32_in(0.0, 1.0);
                if live.is_empty() || roll < 0.4 {
                    let p = g.usize_in(0, prompts.len() - 1);
                    if m.can_allocate_prefill(&prompts[p], 0) {
                        m.register_with_prefix(next_id, &prompts[p]).unwrap();
                        live.push((next_id, p));
                        next_id += 1;
                    }
                } else if roll < 0.55 {
                    let (id, p) = *g.choose(&live);
                    m.insert_prefix(id, &prompts[p], |_| BlockKv::default())
                        .unwrap();
                } else if roll < 0.7 {
                    let (id, _) = *g.choose(&live);
                    let _ = m.extend(id, g.usize_in(0, 6)).unwrap();
                } else if roll < 0.8 {
                    let (id, _) = *g.choose(&live);
                    let len = m.table(id).unwrap().len();
                    m.truncate(id, g.usize_in(1, len)).unwrap();
                } else {
                    let idx = g.usize_in(0, live.len() - 1);
                    let (id, _) = live.swap_remove(idx);
                    m.release(id).unwrap();
                }
                assert!(
                    m.free_blocks() + m.prefix_cached_blocks() <= 32,
                    "over-committed pool"
                );
            }
            for (id, _) in live {
                m.release(id).unwrap();
            }
            assert_eq!(m.num_sequences(), 0);
            // Every non-free block is now held ONLY by the cache.
            assert_eq!(
                m.free_blocks() + m.prefix_cached_blocks(),
                32,
                "leaked blocks (cache/allocator refcounts out of lockstep)"
            );
            m.clear_prefix_cache();
            assert_eq!(m.free_blocks(), 32, "cache held phantom refs");
        });
    }

    #[test]
    fn swap_roundtrip_preserves_radix_identity() {
        // A prefix-cache-attached sequence swaps out and back in: its
        // private blocks leave and return, but the attached chain — node
        // ids, attached refs, cached payloads — must be bit-identical.
        let mut m = pmgr(16);
        m.set_swap_capacity(8);
        let prompt: Vec<i32> = (0..10).collect(); // 2 cached blocks + tail
        m.register_with_prefix(1, &prompt).unwrap();
        m.insert_prefix(1, &prompt, |j| BlockKv {
            k: vec![j as f32],
            v: vec![],
        })
        .unwrap();
        m.release(1).unwrap();
        let a = m.register_with_prefix(2, &prompt).unwrap();
        assert_eq!(a.cached_tokens, 8);
        for _ in 0..3 {
            assert!(m.append_token(2).unwrap()); // len 13, 4 blocks
        }
        let nodes_before = m.seq_attached_nodes(2);
        let refs_before = m.prefix_attached_refs();
        let free_before = m.free_blocks();
        assert_eq!(nodes_before.len(), 2);

        // Out: 2 private blocks leave; the 2 attached stay pinned.
        assert_eq!(m.swap_out(2).unwrap(), Some(2));
        assert!(m.is_swapped(2));
        assert_eq!(m.swapped_blocks(), 2);
        assert_eq!(m.swapped_sequences(), 1);
        assert_eq!(m.free_blocks(), free_before + 2);
        assert_eq!(m.table(2).unwrap().num_blocks(), 2);
        assert_eq!(m.table(2).unwrap().len(), 8); // attached * block_size
        assert_eq!(m.seq_attached_nodes(2), nodes_before);
        assert_eq!(m.prefix_attached_refs(), refs_before);
        // Double swap-out is a caller bug.
        assert!(m.swap_out(2).is_err());

        // In: private blocks return, logical length restores, ledger
        // empties, and the radix attachment never moved.
        assert_eq!(m.swap_in(2).unwrap(), Some(2));
        assert!(!m.is_swapped(2));
        assert_eq!(m.swapped_blocks(), 0);
        assert_eq!(m.free_blocks(), free_before);
        assert_eq!(m.table(2).unwrap().num_blocks(), 4);
        assert_eq!(m.table(2).unwrap().len(), 13);
        assert_eq!(m.seq_attached_nodes(2), nodes_before);
        assert_eq!(m.prefix_attached_refs(), refs_before);
        // Cached payloads still served to a third sequence.
        let a3 = m.register_with_prefix(3, &prompt).unwrap();
        assert_eq!(a3.cached_tokens, 8);
        assert_eq!(a3.kv[1].k, vec![1.0]);
        assert!(m.swap_in(2).is_err()); // not swapped any more

        m.release(2).unwrap();
        m.release(3).unwrap();
        m.clear_prefix_cache();
        assert_eq!(m.free_blocks(), 16);
        assert_eq!(m.unaccounted_blocks(), 0);
    }

    #[test]
    fn swap_out_respects_ledger_capacity_and_zero_means_disabled() {
        let mut m = mgr(16);
        m.register(1, 12).unwrap(); // 3 private blocks
        // Capacity 0 (default): the tier is off.
        assert_eq!(m.swap_out(1).unwrap(), None);
        // Capacity 2 < 3 private blocks: still no.
        m.set_swap_capacity(2);
        assert_eq!(m.swap_out(1).unwrap(), None);
        assert_eq!(m.swapped_sequences(), 0);
        assert_eq!(m.free_blocks(), 13); // refused swap changed nothing
        // Capacity 3: fits exactly; a second victim then finds it full.
        m.set_swap_capacity(3);
        assert_eq!(m.swap_out(1).unwrap(), Some(3));
        m.register(2, 4).unwrap();
        assert_eq!(m.swap_out(2).unwrap(), None, "ledger already full");
        assert_eq!(m.swap_capacity(), 3);
        assert!(m.swap_out(99).is_err()); // unknown sequence
        m.release(1).unwrap();
        m.release(2).unwrap();
        assert_eq!(m.free_blocks(), 16);
    }

    #[test]
    fn swap_in_reports_transient_exhaustion_and_retries() {
        let mut m = mgr(4);
        m.set_swap_capacity(4);
        m.register(1, 12).unwrap(); // 3 blocks
        assert_eq!(m.swap_out(1).unwrap(), Some(3));
        m.register(2, 8).unwrap(); // stranger takes 2 of the 3 freed
        assert_eq!(m.free_blocks(), 2);
        // Only 2 free but 3 needed: stays in the ledger for a later retry.
        assert_eq!(m.swap_in(1).unwrap(), None);
        assert!(m.is_swapped(1));
        assert_eq!(m.free_blocks(), 2); // failed attempt allocated nothing
        m.release(2).unwrap();
        assert_eq!(m.swap_in(1).unwrap(), Some(3));
        assert_eq!(m.table(1).unwrap().len(), 12);
        m.release(1).unwrap();
        assert_eq!(m.free_blocks(), 4);
    }

    #[test]
    fn abort_while_swapped_clears_the_ledger() {
        let mut m = pmgr(16);
        m.set_swap_capacity(8);
        let prompt: Vec<i32> = (0..10).collect();
        m.register_with_prefix(1, &prompt).unwrap();
        m.insert_prefix(1, &prompt, |_| BlockKv::default()).unwrap();
        // The publisher holds plain allocator refs (no attachments), so all
        // 3 of its blocks count as private; the 2 cache-shared ones stay
        // alive cache-side on the tree's own refs.
        m.swap_out(1).unwrap().unwrap();
        assert_eq!(m.swapped_blocks(), 3);
        // Abort lands in release(): resident stub freed, attachments
        // detached, ledger entry gone.
        m.release(1).unwrap();
        assert_eq!(m.swapped_blocks(), 0);
        assert_eq!(m.swapped_sequences(), 0);
        assert_eq!(m.prefix_attached_refs(), 0);
        assert_eq!(m.unaccounted_blocks(), 0);
        m.clear_prefix_cache();
        assert_eq!(m.free_blocks(), 16);
    }

    #[test]
    fn prop_swap_ledger_and_pool_stay_balanced() {
        // Random interleaving of registrations, appends, swap-outs,
        // swap-ins, and releases (some while swapped): at every step
        // free + cached <= total and the ledger only holds live swapped
        // sequences; at quiescence the pool is pristine and the ledger
        // empty.
        testutil::cases(48, 0x54A9, |g| {
            let mut m = pmgr(32);
            m.set_swap_capacity(g.usize_in(0, 16));
            let prompts: Vec<Vec<i32>> = (0..3)
                .map(|p| {
                    let len = 6 + 5 * p;
                    (0..len as i32).map(|i| i + 200 * p as i32).collect()
                })
                .collect();
            let mut live: Vec<u64> = Vec::new(); // resident
            let mut parked: Vec<u64> = Vec::new(); // swapped out
            let mut next_id = 0u64;
            for _ in 0..g.usize_in(1, 60) {
                let roll = g.f32_in(0.0, 1.0);
                if live.is_empty() && parked.is_empty() || roll < 0.35 {
                    let p = g.usize_in(0, prompts.len() - 1);
                    if m.can_allocate_prefill(&prompts[p], 0) {
                        m.register_with_prefix(next_id, &prompts[p]).unwrap();
                        if g.bool(0.5) {
                            m.insert_prefix(next_id, &prompts[p], |_| {
                                BlockKv::default()
                            })
                            .unwrap();
                        }
                        live.push(next_id);
                        next_id += 1;
                    }
                } else if roll < 0.5 && !live.is_empty() {
                    let id = *g.choose(&live);
                    let _ = m.append_token(id).unwrap();
                } else if roll < 0.65 && !live.is_empty() {
                    let idx = g.usize_in(0, live.len() - 1);
                    let id = live[idx];
                    if m.swap_out(id).unwrap().is_some() {
                        live.swap_remove(idx);
                        parked.push(id);
                    }
                } else if roll < 0.8 && !parked.is_empty() {
                    let idx = g.usize_in(0, parked.len() - 1);
                    let id = parked[idx];
                    if m.swap_in(id).unwrap().is_some() {
                        parked.swap_remove(idx);
                        live.push(id);
                    }
                } else if !live.is_empty() || !parked.is_empty() {
                    // Release — sometimes a swapped sequence (abort path).
                    let from_parked = !parked.is_empty()
                        && (live.is_empty() || g.bool(0.4));
                    let id = if from_parked {
                        let idx = g.usize_in(0, parked.len() - 1);
                        parked.swap_remove(idx)
                    } else {
                        let idx = g.usize_in(0, live.len() - 1);
                        live.swap_remove(idx)
                    };
                    m.release(id).unwrap();
                }
                assert!(
                    m.free_blocks() + m.prefix_cached_blocks() <= 32,
                    "over-committed pool"
                );
                assert_eq!(m.swapped_sequences(), parked.len());
                assert!(m.swapped_blocks() <= m.swap_capacity());
            }
            for id in live.into_iter().chain(parked) {
                m.release(id).unwrap();
            }
            assert_eq!(m.swapped_blocks(), 0);
            assert_eq!(m.num_sequences(), 0);
            assert_eq!(m.unaccounted_blocks(), 0, "leaked blocks");
            m.clear_prefix_cache();
            assert_eq!(m.free_blocks(), 32, "cache held phantom refs");
        });
    }
}
