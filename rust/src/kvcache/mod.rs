//! Paged KV-cache management (vLLM-style block allocator).
//!
//! The serving coordinator tracks each sequence's KV footprint in
//! fixed-size *blocks* of token positions, with a free-list allocator,
//! per-sequence block tables, and copy-on-write reference counts (prefix
//! sharing).  This is the scheduler's admission-control currency: a
//! sequence can only be scheduled if its next token has a block to land in.
//!
//! Physical storage note: on real GPUs the block table indexes paged HBM
//! buffers; here the physical KV lives in the dense per-batch cache tensors
//! the AOT decode artifacts carry (see DESIGN.md §2 substitutions).  The
//! *management* layer — allocation, fragmentation, eviction, utilization
//! accounting — is the real vLLM-equivalent machinery and is what the
//! coordinator benches exercise.

pub mod allocator;

pub use allocator::{BlockAllocator, BlockId, BlockTable};

use anyhow::{bail, Result};

/// Configuration of the paged cache.
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    /// Token positions per block (vLLM default 16).
    pub block_size: usize,
    /// Total number of physical blocks available.
    pub num_blocks: usize,
}

impl Default for KvCacheConfig {
    fn default() -> Self {
        Self { block_size: 16, num_blocks: 1024 }
    }
}

/// High-level cache manager: per-sequence block tables over one allocator.
pub struct KvCacheManager {
    config: KvCacheConfig,
    allocator: BlockAllocator,
    tables: std::collections::HashMap<u64, BlockTable>,
}

impl KvCacheManager {
    pub fn new(config: KvCacheConfig) -> Self {
        Self {
            config,
            allocator: BlockAllocator::new(config.num_blocks),
            tables: std::collections::HashMap::new(),
        }
    }

    pub fn config(&self) -> KvCacheConfig {
        self.config
    }

    /// Blocks needed to hold `tokens` positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.config.block_size)
    }

    /// Can a sequence of `tokens` length be admitted right now?
    pub fn can_allocate(&self, tokens: usize) -> bool {
        self.allocator.free_blocks() >= self.blocks_for(tokens)
    }

    /// Register a new sequence with `prompt_tokens` already in the cache.
    pub fn register(&mut self, seq_id: u64, prompt_tokens: usize) -> Result<()> {
        if self.tables.contains_key(&seq_id) {
            bail!("sequence {seq_id} already registered");
        }
        let n = self.blocks_for(prompt_tokens.max(1));
        let blocks = self.allocator.allocate_many(n)?;
        let mut table = BlockTable::new(self.config.block_size);
        for b in blocks {
            table.push(b);
        }
        table.set_len(prompt_tokens);
        self.tables.insert(seq_id, table);
        Ok(())
    }

    /// Extend a sequence by one generated token, allocating a block at the
    /// block boundary.  Returns false (and changes nothing) if the pool is
    /// exhausted — the scheduler's signal to preempt.
    pub fn append_token(&mut self, seq_id: u64) -> Result<bool> {
        let Some(table) = self.tables.get_mut(&seq_id) else {
            bail!("sequence {seq_id} not registered");
        };
        if table.len() == table.num_blocks() * self.config.block_size {
            match self.allocator.allocate() {
                Ok(b) => table.push(b),
                Err(_) => return Ok(false),
            }
        }
        table.set_len(table.len() + 1);
        Ok(true)
    }

    /// Roll a sequence back to `new_len` tokens, releasing whole blocks
    /// past the boundary — speculative decode's rejection rollback
    /// (DESIGN.md §9): draft positions are reserved optimistically via
    /// [`Self::extend`], then truncated away when the verifier rejects.
    /// `new_len` must stay in `1..=len` (a live sequence never shrinks to
    /// zero tokens).
    pub fn truncate(&mut self, seq_id: u64, new_len: usize) -> Result<()> {
        let Some(table) = self.tables.get_mut(&seq_id) else {
            bail!("sequence {seq_id} not registered");
        };
        if new_len == 0 || new_len > table.len() {
            bail!(
                "truncate({seq_id}) to {new_len} outside 1..={}",
                table.len()
            );
        }
        let keep = new_len.div_ceil(self.config.block_size);
        while table.num_blocks() > keep {
            let b = table.pop().expect("num_blocks > keep >= 1");
            self.allocator.free(b)?;
        }
        table.set_len(new_len);
        Ok(())
    }

    /// Optimistically extend a sequence by up to `n` tokens, stopping
    /// early when the pool runs dry; returns how many tokens were
    /// granted.  Speculative decode reserves its draft positions this
    /// way, then [`Self::truncate`]s back to the verified length — a
    /// partially granted burst just means a shorter draft this step, not
    /// a failure.
    pub fn extend(&mut self, seq_id: u64, n: usize) -> Result<usize> {
        for granted in 0..n {
            if !self.append_token(seq_id)? {
                return Ok(granted);
            }
        }
        Ok(n)
    }

    /// Release all blocks of a finished/preempted sequence.
    pub fn release(&mut self, seq_id: u64) -> Result<()> {
        let Some(table) = self.tables.remove(&seq_id) else {
            bail!("sequence {seq_id} not registered");
        };
        for b in table.blocks() {
            self.allocator.free(*b)?;
        }
        Ok(())
    }

    /// Fork a sequence sharing all current blocks copy-on-write (used for
    /// beam/parallel sampling; blocks are refcounted, not copied).
    pub fn fork(&mut self, parent: u64, child: u64) -> Result<()> {
        if self.tables.contains_key(&child) {
            bail!("sequence {child} already registered");
        }
        let Some(table) = self.tables.get(&parent) else {
            bail!("parent {parent} not registered");
        };
        let cloned = table.clone();
        for b in cloned.blocks() {
            self.allocator.add_ref(*b)?;
        }
        self.tables.insert(child, cloned);
        Ok(())
    }

    pub fn table(&self, seq_id: u64) -> Option<&BlockTable> {
        self.tables.get(&seq_id)
    }

    pub fn num_sequences(&self) -> usize {
        self.tables.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.allocator.free_blocks()
    }

    /// Fraction of physical blocks in use.
    pub fn utilization(&self) -> f64 {
        1.0 - self.allocator.free_blocks() as f64 / self.config.num_blocks as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil;

    fn mgr(blocks: usize) -> KvCacheManager {
        KvCacheManager::new(KvCacheConfig { block_size: 4, num_blocks: blocks })
    }

    #[test]
    fn register_and_release_roundtrip() {
        let mut m = mgr(16);
        m.register(1, 10).unwrap(); // 3 blocks of 4
        assert_eq!(m.free_blocks(), 13);
        assert_eq!(m.table(1).unwrap().num_blocks(), 3);
        m.release(1).unwrap();
        assert_eq!(m.free_blocks(), 16);
        assert!(m.release(1).is_err());
    }

    #[test]
    fn append_allocates_at_boundary() {
        let mut m = mgr(16);
        m.register(1, 4).unwrap(); // exactly one block
        assert_eq!(m.table(1).unwrap().num_blocks(), 1);
        assert!(m.append_token(1).unwrap()); // needs block 2
        assert_eq!(m.table(1).unwrap().num_blocks(), 2);
        for _ in 0..3 {
            assert!(m.append_token(1).unwrap()); // fills block 2
        }
        assert_eq!(m.table(1).unwrap().num_blocks(), 2);
        assert!(m.append_token(1).unwrap());
        assert_eq!(m.table(1).unwrap().num_blocks(), 3);
    }

    #[test]
    fn exhaustion_signals_preemption_without_corruption() {
        let mut m = mgr(2);
        m.register(1, 8).unwrap(); // both blocks
        assert_eq!(m.free_blocks(), 0);
        let len_before = m.table(1).unwrap().len();
        assert!(!m.append_token(1).unwrap()); // no room
        assert_eq!(m.table(1).unwrap().len(), len_before);
        m.release(1).unwrap();
        assert_eq!(m.free_blocks(), 2);
    }

    #[test]
    fn fork_shares_blocks_cow() {
        let mut m = mgr(8);
        m.register(1, 8).unwrap(); // 2 blocks
        m.fork(1, 2).unwrap();
        assert_eq!(m.free_blocks(), 6); // shared, not copied
        m.release(1).unwrap();
        assert_eq!(m.free_blocks(), 6); // still referenced by child
        m.release(2).unwrap();
        assert_eq!(m.free_blocks(), 8);
    }

    #[test]
    fn utilization_tracks_usage() {
        let mut m = mgr(10);
        assert_eq!(m.utilization(), 0.0);
        m.register(1, 20).unwrap(); // 5 blocks
        assert!((m.utilization() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn truncate_releases_whole_blocks_past_the_boundary() {
        let mut m = mgr(16); // block_size = 4
        m.register(1, 10).unwrap(); // 3 blocks
        assert_eq!(m.free_blocks(), 13);
        // Shrinking within the same block frees nothing.
        m.truncate(1, 9).unwrap();
        assert_eq!(m.free_blocks(), 13);
        assert_eq!(m.table(1).unwrap().len(), 9);
        // Crossing block boundaries frees the tail blocks.
        m.truncate(1, 4).unwrap();
        assert_eq!(m.free_blocks(), 15);
        assert_eq!(m.table(1).unwrap().num_blocks(), 1);
        m.truncate(1, 1).unwrap();
        assert_eq!(m.table(1).unwrap().num_blocks(), 1);
        // Errors: growth, zero length, unknown sequence.
        assert!(m.truncate(1, 2).is_err());
        assert!(m.truncate(1, 0).is_err());
        assert!(m.truncate(99, 1).is_err());
        m.release(1).unwrap();
        assert_eq!(m.free_blocks(), 16);
    }

    #[test]
    fn extend_then_truncate_is_the_spec_decode_reservation_protocol() {
        let mut m = mgr(4); // 16 token capacity
        m.register(1, 4).unwrap(); // 1 block full
        // Reserve a K=6 draft burst: grows to 10 tokens / 3 blocks.
        assert_eq!(m.extend(1, 6).unwrap(), 6);
        assert_eq!(m.table(1).unwrap().len(), 10);
        assert_eq!(m.table(1).unwrap().num_blocks(), 3);
        // Verifier accepted 1 of 6: roll back to 5 tokens.
        m.truncate(1, 5).unwrap();
        assert_eq!(m.table(1).unwrap().len(), 5);
        assert_eq!(m.table(1).unwrap().num_blocks(), 2);
        assert_eq!(m.free_blocks(), 2);
        m.release(1).unwrap();
        assert_eq!(m.free_blocks(), 4);
    }

    #[test]
    fn extend_grants_partially_when_the_pool_runs_dry() {
        let mut m = mgr(2); // 8 token capacity
        m.register(1, 6).unwrap(); // 2 blocks, 2 slack slots
        assert_eq!(m.extend(1, 5).unwrap(), 2); // only the slack fits
        assert_eq!(m.table(1).unwrap().len(), 8);
        // A zero grant is fine too — and changes nothing.
        assert_eq!(m.extend(1, 3).unwrap(), 0);
        assert_eq!(m.table(1).unwrap().len(), 8);
        assert!(m.extend(99, 1).is_err());
    }

    #[test]
    fn truncate_respects_copy_on_write_refcounts() {
        let mut m = mgr(8);
        m.register(1, 8).unwrap(); // 2 blocks
        m.fork(1, 2).unwrap(); // shares both blocks
        assert_eq!(m.free_blocks(), 6);
        // Parent rolls back past a shared block: the block stays alive for
        // the child (refcount), nothing returns to the pool yet.
        m.truncate(1, 2).unwrap();
        assert_eq!(m.free_blocks(), 6);
        m.release(2).unwrap();
        assert_eq!(m.free_blocks(), 7); // child's refs gone, tail block freed
        m.release(1).unwrap();
        assert_eq!(m.free_blocks(), 8);
    }

    #[test]
    fn prop_extend_truncate_never_leaks() {
        testutil::cases(64, 0x5DEC, |g| {
            let mut m = mgr(32);
            m.register(0, g.usize_in(1, 12)).unwrap();
            for _ in 0..g.usize_in(1, 40) {
                if g.bool(0.5) {
                    let _ = m.extend(0, g.usize_in(0, 9)).unwrap();
                } else {
                    let len = m.table(0).unwrap().len();
                    let target = g.usize_in(1, len);
                    m.truncate(0, target).unwrap();
                }
                // Invariant: blocks exactly cover the logical length.
                let t = m.table(0).unwrap();
                assert!(t.num_blocks() * 4 >= t.len());
                assert!((t.num_blocks() - 1) * 4 < t.len().max(1));
            }
            m.release(0).unwrap();
            assert_eq!(m.free_blocks(), 32, "leaked blocks");
        });
    }

    #[test]
    fn prop_alloc_free_never_leaks() {
        testutil::cases(64, 0xCAFE, |g| {
            let mut m = mgr(32);
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize_in(1, 60) {
                if live.is_empty() || g.bool(0.5) {
                    let toks = g.usize_in(1, 24);
                    if m.can_allocate(toks) {
                        m.register(next_id, toks).unwrap();
                        live.push(next_id);
                        next_id += 1;
                    }
                } else if g.bool(0.3) {
                    let idx = g.usize_in(0, live.len() - 1);
                    let id = live.swap_remove(idx);
                    m.release(id).unwrap();
                } else {
                    let idx = g.usize_in(0, live.len() - 1);
                    let _ = m.append_token(live[idx]).unwrap();
                }
            }
            for id in live {
                m.release(id).unwrap();
            }
            assert_eq!(m.free_blocks(), 32, "leaked blocks");
            assert_eq!(m.num_sequences(), 0);
        });
    }
}
