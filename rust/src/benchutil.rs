//! Minimal benchmarking harness (offline substitute for `criterion`).
//!
//! Criterion-style protocol: warmup, then N timed samples of adaptive
//! iteration count, reporting min / median / p95.  Used by the files under
//! `rust/benches/` (registered with `harness = false`).
//!
//! Besides the human-readable console lines, benches emit machine-readable
//! `BENCH_<name>.json` reports through [`write_bench_report`] — the repo's
//! perf-trajectory format (one file per bench target, an array of flat
//! records, stable keys) consumed by tooling and tracked across PRs.

use std::path::Path;
use std::time::{Duration, Instant};

/// One benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub median: Duration,
    pub min: Duration,
    pub p95: Duration,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<52} time: [{:>10.3?} {:>10.3?} {:>10.3?}]  ({} iters/sample)",
            self.name, self.min, self.median, self.p95, self.iters_per_sample
        );
    }
}

/// Benchmark `f`, returning per-iteration statistics.
///
/// Adaptive: picks an iteration count so one sample takes ~`target_sample`,
/// then collects `samples` samples.
pub fn bench_with(
    name: &str,
    samples: usize,
    target_sample: Duration,
    mut f: impl FnMut(),
) -> BenchResult {
    // Warmup + calibration.
    f();
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().max(Duration::from_nanos(50));
    let iters = (target_sample.as_nanos() / one.as_nanos()).clamp(1, 1_000_000) as u64;

    let mut per_iter: Vec<Duration> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t0.elapsed() / iters as u32);
    }
    per_iter.sort();
    let r = BenchResult {
        name: name.to_string(),
        min: per_iter[0],
        median: per_iter[per_iter.len() / 2],
        p95: per_iter[((per_iter.len() as f64 * 0.95) as usize).min(per_iter.len() - 1)],
        iters_per_sample: iters,
    };
    r.print();
    r
}

/// Default protocol: 20 samples of ~20 ms each.
pub fn bench(name: &str, f: impl FnMut()) -> BenchResult {
    bench_with(name, 20, Duration::from_millis(20), f)
}

/// Quick protocol for expensive bodies (PJRT executions): 10 samples,
/// 1 iteration each.
pub fn bench_slow(name: &str, f: impl FnMut()) -> BenchResult {
    bench_with(name, 10, Duration::from_millis(1), f)
}

impl BenchResult {
    /// The timing fields of this result as JSON `(key, value)` pairs
    /// (nanosecond units), for embedding into a bench report record.
    pub fn json_fields(&self) -> Vec<(&'static str, String)> {
        vec![
            ("median_ns", (self.median.as_nanos() as u64).to_string()),
            ("min_ns", (self.min.as_nanos() as u64).to_string()),
            ("p95_ns", (self.p95.as_nanos() as u64).to_string()),
            ("iters_per_sample", self.iters_per_sample.to_string()),
        ]
    }
}

/// Black-box to stop the optimizer from deleting the benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

// --- machine-readable reports (BENCH_*.json) -----------------------------

/// Escape a string for inclusion inside a JSON string literal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Quote a string as a JSON string literal.
pub fn json_str(s: &str) -> String {
    format!("\"{}\"", json_escape(s))
}

/// Serialize `(key, value)` pairs as one JSON object.  Values are emitted
/// verbatim — quote strings with [`json_str`], format numbers directly.
pub fn json_object(fields: &[(&str, String)]) -> String {
    let body: Vec<String> = fields
        .iter()
        .map(|(k, v)| format!("{}: {v}", json_str(k)))
        .collect();
    format!("{{{}}}", body.join(", "))
}

/// Write a `BENCH_<bench>.json` report: a versioned, provenance-stamped
/// envelope around an array of flat per-measurement records (each an
/// output of [`json_object`]).
///
/// Schema version 2 stamps *where the numbers came from* (`source`,
/// e.g. `"rust-bench"` or `"accounting-sim"`) and echoes the workload
/// `config` knobs, so `flashsampling benchdiff` can refuse to compare
/// reports of different provenance-relevant shape while still matching
/// records across emitters (the per-record `source` field is excluded
/// from record identity).  Values in `config` are emitted verbatim —
/// quote strings with [`json_str`].
pub fn write_bench_report(
    path: &Path,
    bench: &str,
    source: &str,
    config: &[(&str, String)],
    records: &[String],
) -> std::io::Result<()> {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"bench\": {},\n", json_str(bench)));
    out.push_str("  \"schema_version\": 2,\n");
    out.push_str(&format!("  \"source\": {},\n", json_str(source)));
    out.push_str(&format!("  \"config\": {},\n", json_object(config)));
    out.push_str("  \"results\": [\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str("    ");
        out.push_str(r);
        if i + 1 < records.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escaping() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_str("x"), "\"x\"");
    }

    #[test]
    fn json_object_renders_flat_records() {
        let o = json_object(&[
            ("sampler", json_str("gumbel")),
            ("vocab", "2048".to_string()),
            ("ns_per_token", "12.5".to_string()),
        ]);
        assert_eq!(
            o,
            r#"{"sampler": "gumbel", "vocab": 2048, "ns_per_token": 12.5}"#
        );
    }

    #[test]
    fn bench_report_roundtrips_through_json_parser() {
        let path = std::env::temp_dir().join("fs_bench_report_test.json");
        let records = vec![
            json_object(&[("name", json_str("a")), ("v", "1".into())]),
            json_object(&[("name", json_str("b")), ("v", "2".into())]),
        ];
        let config = [("samples", "20".to_string())];
        write_bench_report(&path, "samplers", "rust-bench", &config, &records)
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::json::parse(&text).unwrap();
        assert_eq!(v.req("bench").unwrap().as_str().unwrap(), "samplers");
        assert_eq!(
            v.req("schema_version").unwrap().as_usize().unwrap(),
            2
        );
        assert_eq!(v.req("source").unwrap().as_str().unwrap(), "rust-bench");
        let cfg = v.req("config").unwrap();
        assert_eq!(cfg.req("samples").unwrap().as_usize().unwrap(), 20);
        let results = v.req("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[1].req("v").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn bench_result_json_fields() {
        let r = BenchResult {
            name: "x".into(),
            median: Duration::from_nanos(1500),
            min: Duration::from_nanos(1000),
            p95: Duration::from_nanos(2000),
            iters_per_sample: 10,
        };
        let fields = r.json_fields();
        assert_eq!(fields[0], ("median_ns", "1500".to_string()));
        assert_eq!(fields[3], ("iters_per_sample", "10".to_string()));
    }
}
