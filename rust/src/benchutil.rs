//! Minimal benchmarking harness (offline substitute for `criterion`).
//!
//! Criterion-style protocol: warmup, then N timed samples of adaptive
//! iteration count, reporting min / median / p95.  Used by the files under
//! `rust/benches/` (registered with `harness = false`).

use std::time::{Duration, Instant};

/// One benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub median: Duration,
    pub min: Duration,
    pub p95: Duration,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<52} time: [{:>10.3?} {:>10.3?} {:>10.3?}]  ({} iters/sample)",
            self.name, self.min, self.median, self.p95, self.iters_per_sample
        );
    }
}

/// Benchmark `f`, returning per-iteration statistics.
///
/// Adaptive: picks an iteration count so one sample takes ~`target_sample`,
/// then collects `samples` samples.
pub fn bench_with(
    name: &str,
    samples: usize,
    target_sample: Duration,
    mut f: impl FnMut(),
) -> BenchResult {
    // Warmup + calibration.
    f();
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().max(Duration::from_nanos(50));
    let iters = (target_sample.as_nanos() / one.as_nanos()).clamp(1, 1_000_000) as u64;

    let mut per_iter: Vec<Duration> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t0.elapsed() / iters as u32);
    }
    per_iter.sort();
    let r = BenchResult {
        name: name.to_string(),
        min: per_iter[0],
        median: per_iter[per_iter.len() / 2],
        p95: per_iter[((per_iter.len() as f64 * 0.95) as usize).min(per_iter.len() - 1)],
        iters_per_sample: iters,
    };
    r.print();
    r
}

/// Default protocol: 20 samples of ~20 ms each.
pub fn bench(name: &str, f: impl FnMut()) -> BenchResult {
    bench_with(name, 20, Duration::from_millis(20), f)
}

/// Quick protocol for expensive bodies (PJRT executions): 10 samples,
/// 1 iteration each.
pub fn bench_slow(name: &str, f: impl FnMut()) -> BenchResult {
    bench_with(name, 10, Duration::from_millis(1), f)
}

/// Black-box to stop the optimizer from deleting the benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
