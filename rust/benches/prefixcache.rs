//! Automatic prefix caching end-to-end accounting bench (DESIGN.md §10):
//! drive the radix-tree KV reuse machinery over shared-prefix / multi-turn
//! workloads and report, per scenario, the cached-prefill token reduction,
//! eviction churn, refcount balance (must be zero leaked blocks), the
//! radix+allocator hot-path timing, and the modeled prefill/TTFT win at
//! the measured hit rate (`gpusim::tpot::ModelSpec::prefill_time`).
//!
//! This is an *accounting-level* bench — no AOT artifacts needed, so it
//! runs on any box and in CI (`cargo bench --no-run`).  The scenarios use
//! longer prompts than the tiny AOT artifact set serves: the management
//! layer is the system under test, exactly like `benches/coordinator.rs`.
//!
//! Writes `BENCH_prefixcache.json` (override with `BENCH_OUT`).  The
//! deterministic fields (token counts, hit rates, modeled latencies) are
//! reproduced bit-for-bit by the offline accounting simulation in
//! `python/tests/sim_prefixcache_bench.py` — the committed snapshot's
//! provenance when no Rust toolchain is at hand (`source` field).
//!
//! Acceptance bar asserted here (the bench doubles as a check): the
//! hit-heavy multi-turn scenario must reuse >= 50% of all prefill tokens,
//! and every scenario must release/drain back to a pristine pool.

use std::time::Duration;

use flashsampling::benchutil::{
    bench_with, black_box, json_object, json_str, write_bench_report,
};
use flashsampling::gpusim::specs::B200;
use flashsampling::gpusim::tpot::QWEN3_8B;
use flashsampling::kvcache::{KvCacheConfig, KvCacheManager};
use flashsampling::prefixcache::BlockKv;
use flashsampling::workload::{LengthDist, RequestSpec, SharedPrefix, WorkloadGen};

const BLOCK_SIZE: usize = 16;
const SEED: u64 = 0xCAFE;

/// One workload shape.  The first three scenarios are reproduced
/// bit-for-bit by the offline accounting sim (see module docs); the
/// pressure scenario exercises LRU eviction, which only the Rust manager
/// models, so its numbers come from real bench runs only.
struct Scenario {
    name: &'static str,
    num_blocks: usize,
    /// `Some` => shared-prefix mode; `None` => unique cold prompts.
    mode: Option<SharedPrefix>,
    requests: usize,
}

fn scenarios() -> Vec<Scenario> {
    vec![
        Scenario {
            name: "multi-turn-hit-heavy",
            num_blocks: 4096,
            mode: Some(SharedPrefix {
                num_prefixes: 4,
                prefix_len: 64,
                users: 8,
                turn_len: LengthDist::Fixed(16),
            }),
            requests: 64, // 8 users x 8 turns
        },
        Scenario {
            name: "system-prompt-fanout",
            num_blocks: 4096,
            mode: Some(SharedPrefix {
                num_prefixes: 2,
                prefix_len: 96,
                users: 16,
                turn_len: LengthDist::Uniform(16, 48),
            }),
            requests: 16, // single turn per user
        },
        Scenario {
            name: "unique-cold",
            num_blocks: 4096,
            mode: None,
            requests: 32,
        },
        Scenario {
            name: "multi-turn-under-pressure",
            num_blocks: 64, // tiny pool: LRU eviction churns
            mode: Some(SharedPrefix {
                num_prefixes: 4,
                prefix_len: 64,
                users: 8,
                turn_len: LengthDist::Fixed(16),
            }),
            requests: 64,
        },
    ]
}

fn workload(sc: &Scenario) -> Vec<RequestSpec> {
    let mut g = WorkloadGen::new(SEED, 100.0, 2048);
    g.prefix_mode = sc.mode.clone();
    g.prompt_len = LengthDist::Uniform(64, 192); // unique-cold shape
    g.generate(sc.requests)
}

#[derive(Default)]
struct Drive {
    prefill_tokens: u64,
    cached_tokens: u64,
    evicted: u64,
    leaked: usize,
}

/// Serve the workload at the accounting level: register (attaching any
/// cached prefix), publish the prompt, decode `max_new_tokens`, release.
fn drive(specs: &[RequestSpec], num_blocks: usize) -> Drive {
    let mut kv = KvCacheManager::new(KvCacheConfig {
        block_size: BLOCK_SIZE,
        num_blocks,
        prefix_caching: true,
    });
    let mut out = Drive::default();
    for s in specs {
        let a = kv
            .register_with_prefix(s.id, &s.prompt)
            .expect("pool sized for one live sequence");
        out.prefill_tokens += s.prompt.len() as u64;
        out.cached_tokens += a.cached_tokens as u64;
        kv.insert_prefix(s.id, &s.prompt, |_| BlockKv::default())
            .expect("registered");
        let _ = kv.extend(s.id, s.max_new_tokens).expect("registered");
        kv.release(s.id).expect("registered");
    }
    out.evicted = kv.evicted_blocks();
    out.leaked = num_blocks - kv.free_blocks() - kv.prefix_cached_blocks();
    kv.clear_prefix_cache();
    out.leaked += num_blocks - kv.free_blocks();
    out
}

fn main() {
    println!("## prefixcache — radix-tree KV reuse accounting + modeled TTFT\n");
    let mut records: Vec<String> = Vec::new();

    for sc in scenarios() {
        let specs = workload(&sc);
        let d = drive(&specs, sc.num_blocks);
        let hit_rate = d.cached_tokens as f64 / d.prefill_tokens.max(1) as f64;
        let mean_prompt = d.prefill_tokens as f64 / specs.len() as f64;

        // Modeled prompt-processing time at the MEASURED hit rate, for a
        // production-size prompt (Qwen3-8B on B200, 2048 tokens — the
        // workload's own prompts are artifact-bucket-sized and sit below
        // the weight-stream floor, where prefill time is length-blind).
        const PROD_PROMPT: usize = 2048;
        let cold_ms = QWEN3_8B.prefill_time(&B200, PROD_PROMPT, 0.0) * 1e3;
        let hit_ms = QWEN3_8B.prefill_time(&B200, PROD_PROMPT, hit_rate) * 1e3;
        let reduction_modeled = 1.0 - hit_ms / cold_ms;

        println!(
            "{:<28} hit rate {:>5.1}% | {:>6} of {:>6} prefill tokens cached \
             | evicted {:>4} | leaked {} | modeled prefill {:.2} -> {:.2} ms",
            sc.name,
            hit_rate * 100.0,
            d.cached_tokens,
            d.prefill_tokens,
            d.evicted,
            d.leaked,
            cold_ms,
            hit_ms,
        );

        // The bench doubles as the acceptance check.
        assert_eq!(d.leaked, 0, "{}: leaked blocks", sc.name);
        if sc.name == "multi-turn-hit-heavy" {
            assert!(
                hit_rate >= 0.5,
                "{}: hit rate {hit_rate:.3} below the 50% bar",
                sc.name
            );
        }
        if sc.mode.is_none() {
            assert_eq!(d.cached_tokens, 0, "cold prompts must never hit");
        }

        // Hot-path timing: the full register/insert/extend/release sweep.
        let label = format!("prefixcache/drive/{}", sc.name);
        let timing = bench_with(&label, 10, Duration::from_millis(5), || {
            black_box(drive(&specs, sc.num_blocks).cached_tokens);
        });

        let (np, pl, us, tl) = match &sc.mode {
            Some(m) => (
                m.num_prefixes as i64,
                m.prefix_len as i64,
                m.users as i64,
                format!("{:?}", m.turn_len),
            ),
            None => (0, 0, 0, "-".to_string()),
        };
        let mut fields = vec![
            ("scenario", json_str(sc.name)),
            ("source", json_str("bench")),
            ("block_size", BLOCK_SIZE.to_string()),
            ("num_blocks", sc.num_blocks.to_string()),
            ("num_prefixes", np.to_string()),
            ("prefix_len", pl.to_string()),
            ("users", us.to_string()),
            ("turn_len", json_str(&tl)),
            ("requests", specs.len().to_string()),
            ("prefill_tokens", d.prefill_tokens.to_string()),
            ("cached_prefill_tokens", d.cached_tokens.to_string()),
            ("hit_rate", format!("{hit_rate:.4}")),
            ("cached_token_reduction", format!("{hit_rate:.4}")),
            ("evicted_blocks", d.evicted.to_string()),
            ("leaked_blocks", d.leaked.to_string()),
            ("mean_prompt_tokens", format!("{mean_prompt:.1}")),
            ("model", json_str(QWEN3_8B.name)),
            ("gpu", json_str(B200.name)),
            ("modeled_prompt_tokens", PROD_PROMPT.to_string()),
            ("modeled_prefill_cold_ms", format!("{cold_ms:.3}")),
            ("modeled_prefill_hit_ms", format!("{hit_ms:.3}")),
            ("modeled_prefill_reduction", format!("{reduction_modeled:.4}")),
        ];
        fields.extend(timing.json_fields());
        records.push(json_object(&fields));
    }

    let out = std::env::var("BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_prefixcache.json".to_string());
    let path = std::path::PathBuf::from(out);
    let config = [
        ("block_size", BLOCK_SIZE.to_string()),
        ("seed", SEED.to_string()),
    ];
    write_bench_report(&path, "prefixcache", "rust-bench", &config, &records)
        .expect("writing report");
    println!("\nwrote {} ({} scenarios)", path.display(), records.len());
}
