//! Open-loop serving latency bench (ROADMAP item 5 tail): TTFT / ITL
//! percentiles vs arrival rate, with chunked prefill off and on — the
//! standing regression scenario for the continuous-batching work of
//! DESIGN.md §12.
//!
//! This is an *accounting-level* bench like `benches/coordinator.rs` and
//! `benches/prefixcache.rs`: it drives the REAL scheduler (`plan`) and the
//! REAL `KvCacheManager` through `testutil::schedsim`, so no AOT artifacts
//! are needed and it runs on any box.  Latencies are reported in the
//! simulator's token-weighted units (a prefill of T tokens costs T, a
//! chunk window costs its take, a decode or idle step costs 1) — the same
//! cost model the TTFT-under-load regression test in
//! `rust/tests/chunked_prefill.rs` asserts against.
//!
//! The workload is a fixed deterministic mix — every 8th request is a
//! 60-token "monopolist" prompt, the rest are shorts — swept across
//! arrival intervals (open loop: arrival i lands at step `i * interval`,
//! regardless of service progress).  The chunked leg runs
//! `prefill_chunk_tokens = 16` with `chunk_interleave = true`, the
//! configuration whose odd steps yield to shorts and decode.
//!
//! Writes `BENCH_serving.json` (override with `BENCH_OUT`).  The
//! deterministic fields (completion counts, weighted TTFT/ITL percentiles,
//! makespan, window counts) are reproduced bit-for-bit by the offline
//! accounting simulation in `python/tests/sim_serving_bench.py` — the
//! committed snapshot's provenance when no Rust toolchain is at hand
//! (`source` field), exactly like `BENCH_prefixcache.json`.
//!
//! Acceptance bars asserted here (the bench doubles as a check): every
//! request completes its full token budget in both legs, the chunked leg
//! actually opens windows, and at the densest arrival rate the shorts'
//! p95 TTFT with chunking+interleave is no worse than without.

use std::collections::HashMap;
use std::time::Duration;

use flashsampling::benchutil::{
    bench_with, black_box, json_object, json_str, write_bench_report,
};
use flashsampling::testutil::schedsim::{
    run, Finish, SimConfig, SimOutcome, SimRequest,
};
use flashsampling::trace::TraceLevel;

const REQUESTS: u64 = 48;
/// Every 8th prompt is the long monopolist (fits the 64 bucket, so the
/// unchunked leg serves it too — in one 60-weight step).
const LONG_PROMPT: usize = 60;

fn prompt_len(i: u64) -> usize {
    if i % 8 == 3 {
        LONG_PROMPT
    } else {
        6 + ((i * 5) % 19) as usize
    }
}

fn gen_len(i: u64) -> usize {
    2 + ((i * 3) % 7) as usize
}

fn script(interval: u64) -> Vec<SimRequest> {
    (0..REQUESTS)
        .map(|i| SimRequest {
            id: i,
            prompt_len: prompt_len(i),
            max_new_tokens: gen_len(i),
            arrival_step: i * interval,
        })
        .collect()
}

fn sim_cfg(chunk: usize, interleave: bool) -> SimConfig {
    // 4096 blocks x 16 tokens: far above the live set, so admission never
    // constrains the schedule — this bench measures scheduling latency,
    // not memory pressure (the swap tier has its own tests).
    let mut cfg = SimConfig::small(4096);
    cfg.sched.prefill_chunk_tokens = chunk;
    cfg.sched.chunk_interleave = interleave;
    cfg
}

/// `sorted[floor(len * q)]`, clamped — the same truncating percentile the
/// python mirror implements.
fn pct(sorted: &[u64], q: f64) -> u64 {
    sorted[((sorted.len() as f64 * q) as usize).min(sorted.len() - 1)]
}

struct Stats {
    completed: usize,
    ttft_p50: u64,
    ttft_p95: u64,
    short_ttft_p95: u64,
    itl_p50: u64,
    itl_p95: u64,
    makespan: u64,
}

fn stats(out: &HashMap<u64, SimOutcome>) -> Stats {
    let mut ttft: Vec<u64> = Vec::new();
    let mut short_ttft: Vec<u64> = Vec::new();
    let mut itl: Vec<u64> = Vec::new();
    let mut makespan = 0u64;
    let mut completed = 0usize;
    for (&id, o) in out {
        assert_eq!(o.finish, Some(Finish::Done), "request {id} did not finish");
        assert_eq!(o.tokens.len(), gen_len(id), "request {id} token budget");
        completed += 1;
        let t0 = o.ttft_weighted.expect("completed => first token");
        ttft.push(t0);
        if prompt_len(id) < 32 {
            short_ttft.push(t0);
        }
        for w in o.token_times.windows(2) {
            itl.push(w[1] - w[0]);
        }
        makespan = makespan.max(*o.token_times.last().unwrap());
    }
    ttft.sort_unstable();
    short_ttft.sort_unstable();
    itl.sort_unstable();
    Stats {
        completed,
        ttft_p50: pct(&ttft, 0.5),
        ttft_p95: pct(&ttft, 0.95),
        short_ttft_p95: pct(&short_ttft, 0.95),
        itl_p50: pct(&itl, 0.5),
        itl_p95: pct(&itl, 0.95),
        makespan,
    }
}

fn main() {
    println!("## serving — open-loop TTFT/ITL vs arrival rate (weighted units)\n");
    let mut records: Vec<String> = Vec::new();
    let legs: [(&str, usize, bool); 2] =
        [("whole", 0, false), ("chunked-interleave", 16, true)];

    for interval in [1u64, 2, 4] {
        let reqs = script(interval);
        let mut short_p95_by_leg: Vec<u64> = Vec::new();
        for (name, chunk, interleave) in legs {
            let mut sim = flashsampling::testutil::schedsim::Sim::new(
                sim_cfg(chunk, interleave),
            );
            sim.drive(&reqs);
            let s = stats(&sim.outcomes);
            assert_eq!(s.completed as u64, REQUESTS);
            if chunk > 0 {
                assert!(
                    sim.chunk_windows > 0,
                    "chunked leg must open windows for the 60-token prompts"
                );
            }
            short_p95_by_leg.push(s.short_ttft_p95);

            println!(
                "interval {interval} {name:<18} ttft p50/p95 {:>4}/{:>4} | \
                 short p95 {:>4} | itl p50/p95 {:>2}/{:>3} | makespan {:>5} \
                 | windows {}",
                s.ttft_p50,
                s.ttft_p95,
                s.short_ttft_p95,
                s.itl_p50,
                s.itl_p95,
                s.makespan,
                sim.chunk_windows,
            );

            // Hot-path timing: the full open-loop drive (scheduler + KV
            // bookkeeping for 48 requests).
            let label = format!("serving/drive/interval{interval}/{name}");
            let cfg = sim_cfg(chunk, interleave);
            let timing = bench_with(&label, 10, Duration::from_millis(5), || {
                black_box(run(cfg.clone(), &reqs).len());
            });

            let mut fields = vec![
                ("scenario", json_str(name)),
                ("source", json_str("bench")),
                ("arrival_interval", interval.to_string()),
                ("chunk", chunk.to_string()),
                ("interleave", interleave.to_string()),
                ("requests", REQUESTS.to_string()),
                ("completed", s.completed.to_string()),
                ("ttft_p50_w", s.ttft_p50.to_string()),
                ("ttft_p95_w", s.ttft_p95.to_string()),
                ("short_ttft_p95_w", s.short_ttft_p95.to_string()),
                ("itl_p50_w", s.itl_p50.to_string()),
                ("itl_p95_w", s.itl_p95.to_string()),
                ("makespan_w", s.makespan.to_string()),
                ("chunk_windows", sim.chunk_windows.to_string()),
            ];
            fields.extend(timing.json_fields());
            records.push(json_object(&fields));
        }
        // The regression bar: under load, chunking+interleave must not
        // worsen the shorts' tail TTFT (at the densest rate it improves
        // it — the committed snapshot records the separation).
        assert!(
            short_p95_by_leg[1] <= short_p95_by_leg[0],
            "interval {interval}: chunked short p95 {} > whole {}",
            short_p95_by_leg[1],
            short_p95_by_leg[0],
        );
    }

    // Flight-recorder overhead guard (DESIGN.md §14): the densest-rate
    // chunked drive at every `trace_level`.  `off` (the default) must
    // stay free — one predictable branch per emission site — so the
    // tracked number is the full/off median ratio in the snapshot; the
    // assertion here is only a runaway guard against an emission site
    // growing work outside its `trace.on()` gate.
    println!("\n## serving — flight-recorder overhead (interval 1, chunked)\n");
    let reqs = script(1);
    let mut medians: Vec<u64> = Vec::new();
    for level in [TraceLevel::Off, TraceLevel::Lifecycle, TraceLevel::Full] {
        let mut cfg = sim_cfg(16, true);
        cfg.trace_level = level;
        // The gate itself: off emits nothing, on emits a bounded stream.
        let mut probe = flashsampling::testutil::schedsim::Sim::new(cfg.clone());
        probe.drive(&reqs);
        let events = probe.trace.total();
        match level {
            TraceLevel::Off => assert_eq!(events, 0, "off leg recorded events"),
            _ => assert!(events > 0, "{level} leg recorded nothing"),
        }
        let label = format!("serving/trace/{level}");
        let timing = bench_with(&label, 10, Duration::from_millis(5), || {
            black_box(run(cfg.clone(), &reqs).len());
        });
        medians.push(timing.median.as_nanos() as u64);
        let mut fields = vec![
            ("scenario", json_str("trace-overhead")),
            ("source", json_str("bench")),
            ("trace_level", json_str(level.name())),
            ("arrival_interval", "1".to_string()),
            ("requests", REQUESTS.to_string()),
            ("trace_events", events.to_string()),
        ];
        fields.extend(timing.json_fields());
        records.push(json_object(&fields));
    }
    let ratio = medians[2] as f64 / medians[0].max(1) as f64;
    println!("\nfull/off median ratio: {ratio:.3}");
    records.push(json_object(&[
        ("scenario", json_str("trace-overhead-ratio")),
        ("source", json_str("bench")),
        ("full_over_off", format!("{ratio:.4}")),
    ]));
    assert!(
        ratio < 25.0,
        "full-level tracing blew up the drive {ratio:.1}x over off"
    );

    let out = std::env::var("BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_serving.json".to_string());
    let path = std::path::PathBuf::from(out);
    let config = [
        ("requests", REQUESTS.to_string()),
        ("long_prompt", LONG_PROMPT.to_string()),
    ];
    write_bench_report(&path, "serving", "rust-bench", &config, &records)
        .expect("writing report");
    println!("\nwrote {} ({} records)", path.display(), records.len());
}
