//! Native sampler benchmarks (the Rust half of Tables 4/5's comparison).
//!
//! Measures the per-row cost of the paper's algorithm chain on this CPU:
//! fused-style streaming Gumbel-Max vs the materialized-logits baseline vs
//! the grouped/online/distributed variants, across vocabulary sizes, plus
//! the Gumbel-Top-k extension (Appendix D.6).

use flashsampling::benchutil::{bench, black_box};
use flashsampling::sampling::{
    distributed, grouped, gumbel, multinomial, online, philox, topk, Key,
    Transform,
};

fn toy_logits(v: usize, seed: u64) -> Vec<f32> {
    let key = Key::from_seed(seed);
    (0..v)
        .map(|i| 3.0 * (philox::uniform_at(key, i as u32, 0, 3, 0) - 0.5))
        .collect()
}

fn main() {
    let key = Key::new(11, 22);
    let t = Transform::default();
    println!("## samplers — per-row cost across vocabulary sizes\n");
    for v in [2_048usize, 32_768, 151_936] {
        let logits = toy_logits(v, 9);
        let mut step = 0u32;
        bench(&format!("gumbel_max/streaming/V={v}"), || {
            step = step.wrapping_add(1);
            black_box(gumbel::sample_row(&logits, &t, key, 0, step));
        });
        bench(&format!("gumbel_max/tiled_2048/V={v}"), || {
            step = step.wrapping_add(1);
            black_box(gumbel::sample_row_tiled(&logits, &t, key, 0, step, 2048));
        });
        bench(&format!("multinomial_baseline/V={v}"), || {
            step = step.wrapping_add(1);
            black_box(multinomial::sample_row(&logits, &t, key, 0, step));
        });
        bench(&format!("grouped_I2/g=2048/V={v}"), || {
            step = step.wrapping_add(1);
            black_box(grouped::sample_row(&logits, 2048, &t, key, 0, step));
        });
        bench(&format!("online_I3/g=2048/V={v}"), || {
            step = step.wrapping_add(1);
            black_box(online::sample_row(&logits, 2048, &t, key, 0, step));
        });
        bench(&format!("topk8_tiled/V={v}"), || {
            step = step.wrapping_add(1);
            black_box(topk::topk_tiled(&logits, &t, key, 0, step, 8, 2048));
        });
        // Distributed merge cost (the leader-side work per row at TP=8).
        let shards: Vec<distributed::ShardSummary> = (0..8)
            .map(|r| {
                let vs = v / 8;
                distributed::shard_summary(
                    r as u32, &logits[r as usize * vs..(r as usize + 1) * vs],
                    r as usize * vs, &t, key, 0, 0,
                )
            })
            .collect();
        bench(&format!("distributed_merge/tp8/V={v}"), || {
            black_box(distributed::merge_pathwise(&shards));
            black_box(distributed::merge_by_mass(&shards, key, 0, 0));
        });
    }
}
