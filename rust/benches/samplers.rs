//! Native sampler benchmarks (the Rust half of Tables 4/5's comparison),
//! driven entirely through typed `SamplerSpec` selection.
//!
//! Measures per-token sampling cost across a batch × vocabulary grid for
//! every registered paper sampler (specs parsed once, never hard-coded
//! call sites) in two modes — `uniform` (`sample_batch`, one shared
//! transform) and `per_row` (`sample_batch_rows`, mixed per-row
//! temperatures) — plus the tiled-gumbel variant.  Each row is the
//! sampler's FULL per-row pipeline — for `distributed` that includes
//! computing every shard summary, not just the O(ranks) leader merge (the
//! leader-merge-only cost is measured in `benches/tp_fanout.rs`).  Besides
//! the console lines, writes the machine-readable `BENCH_samplers.json`
//! (override the path with the `BENCH_OUT` environment variable) — the
//! seed of the repo's perf trajectory.

use flashsampling::benchutil::{
    bench_with, black_box, json_object, json_str, write_bench_report,
};
#[allow(unused_imports)]
use flashsampling::sampling::ExactSampler;
use flashsampling::sampling::{philox, Key, RowCtx, SamplerSpec, Transform};
use std::time::Duration;

/// The benchmarked sampler specs: all six registry names (default
/// parameters) plus the tiled fused-kernel-shaped gumbel variant.
const SPECS: [&str; 7] = [
    "gumbel",
    "gumbel:tile=2048",
    "multinomial",
    "grouped:group=2048",
    "online:group=2048",
    "distributed:ranks=8",
    "topk:k=8,tile=2048",
];

/// Batch × vocabulary grid (paper-shaped vocabulary sizes).
const BATCHES: [usize; 2] = [1, 8];
const VOCABS: [usize; 3] = [2_048, 32_768, 151_936];

fn toy_logits(n: usize, seed: u64) -> Vec<f32> {
    let key = Key::from_seed(seed);
    (0..n)
        .map(|i| 3.0 * (philox::uniform_at(key, i as u32, 0, 3, 0) - 0.5))
        .collect()
}

/// One full VOCABS x BATCHES x SPECS sweep.  `sample` runs the benched
/// body for one (sampler, logits grid cell, step); everything else —
/// record schema, timing config, labels — is shared so the uniform and
/// per-row modes stay comparable by construction.
fn run_grid(
    mode: &str,
    records: &mut Vec<String>,
    sample: impl Fn(&dyn ExactSampler, &[f32], usize, usize, u32),
) {
    for &vocab in &VOCABS {
        for &batch in &BATCHES {
            let logits = toy_logits(batch * vocab, 9);
            for spec_str in SPECS {
                // Config strings parse once into the typed SamplerSpec; the
                // canonical Display form is what lands in the report.
                let spec: SamplerSpec =
                    spec_str.parse().expect("bench spec is valid");
                let sampler = spec.build().expect("bench spec builds");
                let mut step = 0u32;
                let label = format!("{spec}/B={batch}/V={vocab}/{mode}");
                let result =
                    bench_with(&label, 15, Duration::from_millis(10), || {
                        step = step.wrapping_add(1);
                        sample(sampler.as_ref(), &logits, vocab, batch, step);
                    });
                // One benched call samples `batch` tokens.
                let ns_per_token =
                    result.median.as_nanos() as f64 / batch as f64;
                let mut fields = vec![
                    ("sampler", json_str(sampler.name())),
                    ("spec", json_str(&spec.to_string())),
                    ("mode", json_str(mode)),
                    ("batch", batch.to_string()),
                    ("vocab", vocab.to_string()),
                    ("ns_per_token", format!("{ns_per_token:.1}")),
                ];
                for (k, v) in result.json_fields() {
                    fields.push((k, v));
                }
                records.push(json_object(&fields));
            }
        }
    }
}

fn main() {
    let key = Key::new(11, 22);
    let t = Transform::default();
    let mut records: Vec<String> = Vec::new();

    println!("## samplers — ns/token across the batch x vocab grid (typed SamplerSpec selection)\n");
    run_grid("uniform", &mut records, |s, logits, vocab, _batch, step| {
        black_box(s.sample_batch(logits, vocab, &t, key, step));
    });

    // Per-row API: the same grid through sample_batch_rows with one
    // transform per row (mixed temperatures) — the entry point the
    // coalescing scheduler relies on.  The benched body includes building
    // the per-row contexts, which IS the per-row API's real per-call cost;
    // it must stay in the noise relative to the uniform path.
    println!("\n## samplers/per-row — heterogeneous batches via sample_batch_rows\n");
    run_grid("per_row", &mut records, |s, logits, vocab, batch, step| {
        let transforms: Vec<Transform> = (0..batch)
            .map(|b| Transform::with_temperature(0.5 + 0.25 * b as f32))
            .collect();
        let ctxs: Vec<RowCtx<'_>> = transforms
            .iter()
            .enumerate()
            .map(|(b, tr)| RowCtx { transform: tr, key, row: b as u32, step })
            .collect();
        black_box(s.sample_batch_rows(logits, vocab, &ctxs));
    });

    let out = std::env::var("BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_samplers.json".to_string());
    let path = std::path::PathBuf::from(out);
    let config = [
        ("batches", "[1, 8]".to_string()),
        ("vocabs", "[2048, 32768, 151936]".to_string()),
        ("specs", SPECS.len().to_string()),
    ];
    write_bench_report(&path, "samplers", "rust-bench", &config, &records)
        .expect("writing report");
    println!(
        "\nwrote {} ({} records: {} specs x {} batches x {} vocabs x 2 modes)",
        path.display(),
        records.len(),
        SPECS.len(),
        BATCHES.len(),
        VOCABS.len()
    );
}
