//! Native sampler benchmarks (the Rust half of Tables 4/5's comparison),
//! driven entirely through the `ExactSampler` registry.
//!
//! Measures per-token sampling cost across a batch × vocabulary grid for
//! every registered paper sampler (selected by config string, never by
//! hard-coded call sites), plus the tiled-gumbel variant.  Each row is the
//! sampler's FULL per-row pipeline — for `distributed` that includes
//! computing every shard summary, not just the O(ranks) leader merge (the
//! leader-merge-only cost is measured in `benches/tp_fanout.rs`).  Besides
//! the console lines, writes the machine-readable `BENCH_samplers.json`
//! (override the path with the `BENCH_OUT` environment variable) — the
//! seed of the repo's perf trajectory.

use flashsampling::benchutil::{
    bench_with, black_box, json_object, json_str, write_bench_report,
};
#[allow(unused_imports)]
use flashsampling::sampling::ExactSampler;
use flashsampling::sampling::{build_sampler, philox, Key, Transform};
use std::time::Duration;

/// The benchmarked sampler specs: all six registry names (default
/// parameters) plus the tiled fused-kernel-shaped gumbel variant.
const SPECS: [&str; 7] = [
    "gumbel",
    "gumbel:tile=2048",
    "multinomial",
    "grouped:group=2048",
    "online:group=2048",
    "distributed:ranks=8",
    "topk:k=8,tile=2048",
];

/// Batch × vocabulary grid (paper-shaped vocabulary sizes).
const BATCHES: [usize; 2] = [1, 8];
const VOCABS: [usize; 3] = [2_048, 32_768, 151_936];

fn toy_logits(n: usize, seed: u64) -> Vec<f32> {
    let key = Key::from_seed(seed);
    (0..n)
        .map(|i| 3.0 * (philox::uniform_at(key, i as u32, 0, 3, 0) - 0.5))
        .collect()
}

fn main() {
    let key = Key::new(11, 22);
    let t = Transform::default();
    println!("## samplers — ns/token across the batch x vocab grid (via the ExactSampler registry)\n");

    let mut records: Vec<String> = Vec::new();
    for &vocab in &VOCABS {
        for &batch in &BATCHES {
            let logits = toy_logits(batch * vocab, 9);
            for spec in SPECS {
                let sampler = build_sampler(spec).expect("bench spec is valid");
                let mut step = 0u32;
                let label = format!("{spec}/B={batch}/V={vocab}");
                let result =
                    bench_with(&label, 15, Duration::from_millis(10), || {
                        step = step.wrapping_add(1);
                        black_box(sampler.sample_batch(
                            &logits, vocab, &t, key, step,
                        ));
                    });
                // One benched call samples `batch` tokens.
                let ns_per_token =
                    result.median.as_nanos() as f64 / batch as f64;
                let mut fields = vec![
                    ("sampler", json_str(sampler.name())),
                    ("spec", json_str(spec)),
                    ("batch", batch.to_string()),
                    ("vocab", vocab.to_string()),
                    ("ns_per_token", format!("{ns_per_token:.1}")),
                ];
                for (k, v) in result.json_fields() {
                    fields.push((k, v));
                }
                records.push(json_object(&fields));
            }
        }
    }

    let out = std::env::var("BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_samplers.json".to_string());
    let path = std::path::PathBuf::from(out);
    write_bench_report(&path, "samplers", &records).expect("writing report");
    println!(
        "\nwrote {} ({} records: {} specs x {} batches x {} vocabs)",
        path.display(),
        records.len(),
        SPECS.len(),
        BATCHES.len(),
        VOCABS.len()
    );
}
