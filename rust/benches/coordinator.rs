//! Coordinator hot-path benchmarks: scheduler planning, KV-cache
//! bookkeeping, workload generation — the L3 costs that must stay far
//! below a decode step (the paper's L3 must not become the bottleneck).

use flashsampling::benchutil::{bench, black_box};
use flashsampling::coordinator::request::{Request, SamplingParams, SeqState, Sequence};
use flashsampling::coordinator::scheduler::{plan, SchedulerConfig};
use flashsampling::kvcache::{KvCacheConfig, KvCacheManager};
use flashsampling::workload::WorkloadGen;

fn seqs(n: usize, state: SeqState) -> Vec<Sequence> {
    (0..n)
        .map(|i| {
            let mut s = Sequence::new(Request::new(
                i as u64,
                vec![1; 16],
                SamplingParams::default(),
            ));
            s.state = state;
            s
        })
        .collect()
}

fn main() {
    println!("## coordinator — scheduler + KV cache hot paths\n");
    let cfg = SchedulerConfig {
        decode_buckets: vec![1, 2, 4, 8],
        prefill_t_buckets: vec![16, 64],
        prefill_b: 4,
        max_concurrency: 8,
        max_tokens_per_step: 1,
        aging_steps: 32,
        prefill_chunk_tokens: 0,
        chunk_interleave: false,
    };
    let waiting = seqs(32, SeqState::Waiting);
    let running = seqs(8, SeqState::Running);
    bench("scheduler/plan/32waiting_8running", || {
        black_box(plan(&cfg, &waiting, &running, |_, _| true, |_| 0, 100));
    });
    let no_waiting: Vec<Sequence> = Vec::new();
    bench("scheduler/plan/decode_only", || {
        black_box(plan(&cfg, &no_waiting, &running, |_, _| true, |_| 0, 100));
    });

    let kv_cfg = KvCacheConfig {
        block_size: 16,
        num_blocks: 512,
        prefix_caching: false,
    };
    bench("kvcache/register_release_seq64toks", || {
        let mut m = KvCacheManager::new(kv_cfg);
        for id in 0..32u64 {
            m.register(id, 64).unwrap();
        }
        for id in 0..32u64 {
            m.release(id).unwrap();
        }
        black_box(m.free_blocks());
    });
    bench("kvcache/append_token_x256", || {
        let mut m = KvCacheManager::new(kv_cfg);
        m.register(0, 16).unwrap();
        for _ in 0..256 {
            m.append_token(0).unwrap();
        }
        m.release(0).unwrap();
        black_box(m.free_blocks());
    });

    bench("workload/generate_poisson_x256", || {
        let g = WorkloadGen::new(3, 8.0, 2048);
        black_box(g.generate(256));
    });
}
