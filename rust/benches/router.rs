//! Multi-replica router bench (DESIGN.md §13): aggregate throughput and
//! tail latency vs replica count × dispatch policy, on a multi-turn
//! session workload over shared system prompts — the traffic shape
//! prefix-affinity routing exists for.
//!
//! Accounting-level like `benches/serving.rs`: it drives the REAL
//! `Router` over `SimReplica` backends (real `KvCacheManager` + radix
//! prefix cache, real dispatch function), so no AOT artifacts are needed
//! and it runs on any box.  Latencies are the sim's token-weighted units
//! (a prefill batch costs its longest uncached suffix, a decode step
//! costs 1); a request's latency is its owner replica's weighted time
//! from submission to completion, and the makespan is the largest
//! per-replica weighted time — aggregate throughput is
//! `tokens_generated / makespan_w`.
//!
//! Workload: 12 sessions × 4 turns (48 requests), each session opening
//! with one of 6 shared 32-token system prompts and growing by a
//! 16-token turn chunk per wave; waves are submitted together and
//! drained to quiescence (closed loop), so dispatch — not arrival
//! timing — is the only variable across policies.  Within each wave the
//! sessions are submitted in rotated order `(turn + k) % SESSIONS`:
//! with a fixed order and full drains, least-loaded's deterministic
//! tiebreaks send every session to the same replica every turn (perfect
//! accidental affinity), and the comparison measures nothing.  Rotation
//! models arrival jitter — any real open-loop trace perturbs the order —
//! and makes the policies separate.
//!
//! Writes `BENCH_router.json` (override with `BENCH_OUT`).  The
//! deterministic fields are reproduced bit-for-bit by
//! `python/tests/sim_router_bench.py` — the committed snapshot's
//! provenance when no Rust toolchain is at hand (`source` field),
//! exactly like `BENCH_serving.json`.
//!
//! Acceptance bars asserted here (the bench doubles as a check): every
//! request completes its token budget under every grid point, prefill
//! token totals are placement-invariant, and at 2+ replicas
//! prefix-affinity achieves strictly more cached prefill tokens than
//! least-loaded without starving any replica.

use std::time::Duration;

use flashsampling::benchutil::{
    bench_with, black_box, json_object, json_str, write_bench_report,
};
use flashsampling::coordinator::{Request, SamplingParams};
use flashsampling::router::{
    sim_router, DispatchPolicy, EngineBackend, SimReplicaConfig,
};

const SESSIONS: u64 = 12;
const TURNS: u64 = 4;
const REQUESTS: u64 = SESSIONS * TURNS;
const NUM_SYS: u64 = 6;
const MAX_NEW: usize = 4;

/// Session `session`'s prompt after `turn + 1` turns: a shared 32-token
/// system prompt (one of `NUM_SYS`) plus one 16-token chunk per turn.
/// Same integer recipe as `repro router-identity` and the Python mirror.
fn session_prompt(session: u64, turn: u64) -> Vec<i32> {
    let sys = session % NUM_SYS;
    let mut p: Vec<i32> =
        (0..32u64).map(|j| ((sys * 97 + j * 13 + 5) % 2048) as i32).collect();
    for t in 0..=turn {
        p.extend(
            (0..16u64).map(|j| ((session * 59 + t * 31 + j * 7 + 11) % 2048) as i32),
        );
    }
    p
}

/// `sorted[floor(len * q)]`, clamped — the same truncating percentile the
/// serving bench and the Python mirror implement.
fn pct(sorted: &[u64], q: f64) -> u64 {
    sorted[((sorted.len() as f64 * q) as usize).min(sorted.len() - 1)]
}

#[derive(Default)]
struct DriveOut {
    /// (id, weighted submit→completion latency) per finished request.
    latency: Vec<(u64, u64)>,
    completed: u64,
    tokens_generated: u64,
    prefill_tokens: u64,
    cached_prefill_tokens: u64,
    makespan_w: u64,
    per_replica_completed: Vec<u64>,
}

fn drive(n: usize, policy: DispatchPolicy) -> DriveOut {
    let mut r = sim_router(n, policy, SimReplicaConfig::default());
    let mut out = DriveOut::default();
    for turn in 0..TURNS {
        // Rotated submission order (see module docs): the id is derived
        // from the session, not the position, so ids stay stable.
        for k in 0..SESSIONS {
            let session = (turn + k) % SESSIONS;
            let id = turn * SESSIONS + session;
            let req = Request::new(
                id,
                session_prompt(session, turn),
                SamplingParams { max_new_tokens: MAX_NEW, ..Default::default() },
            );
            r.submit(req).expect("submit");
        }
        let mut idle = 0u32;
        while r.pending() > 0 {
            let step = r.step().expect("sim step");
            if step.is_empty() {
                idle += 1;
                assert!(idle < 64, "router bench livelock");
            } else {
                idle = 0;
            }
            for c in step {
                out.completed += 1;
                out.tokens_generated += c.tokens.len() as u64;
                let w = c.timing.ttft.expect("completed with tokens");
                out.latency.push((c.id, w.as_micros() as u64));
            }
        }
    }
    for e in r.replicas() {
        out.prefill_tokens += e.metrics.prefill_tokens;
        out.cached_prefill_tokens += e.metrics.cached_prefill_tokens;
        out.makespan_w = out.makespan_w.max(e.wtime());
        out.per_replica_completed.push(e.metrics.requests_completed);
    }
    out
}

fn main() {
    println!(
        "## router — session throughput/latency vs replicas x dispatch \
         policy (weighted units)\n"
    );
    let mut records: Vec<String> = Vec::new();
    let policies = [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::LeastLoaded,
        DispatchPolicy::PrefixAffinity,
    ];

    for n in [1usize, 2, 4] {
        let mut cached_by_policy: Vec<u64> = Vec::new();
        let mut prefill_by_policy: Vec<u64> = Vec::new();
        for policy in policies {
            let out = drive(n, policy);
            assert_eq!(out.completed, REQUESTS, "r{n}/{policy}: dropped requests");
            assert_eq!(
                out.tokens_generated,
                REQUESTS * MAX_NEW as u64,
                "r{n}/{policy}: token budget"
            );
            let mut lat: Vec<u64> = out.latency.iter().map(|&(_, w)| w).collect();
            let mut warm: Vec<u64> = out
                .latency
                .iter()
                .filter(|&&(id, _)| id >= SESSIONS)
                .map(|&(_, w)| w)
                .collect();
            lat.sort_unstable();
            warm.sort_unstable();
            let min_completed =
                *out.per_replica_completed.iter().min().expect(">=1 replica");
            cached_by_policy.push(out.cached_prefill_tokens);
            prefill_by_policy.push(out.prefill_tokens);

            println!(
                "replicas {n} {policy:<16} lat p50/p95 {:>4}/{:>4} | warm p95 \
                 {:>4} | cached/prefill {:>5}/{:>5} | makespan {:>4} | \
                 per-replica {:?}",
                pct(&lat, 0.5),
                pct(&lat, 0.95),
                pct(&warm, 0.95),
                out.cached_prefill_tokens,
                out.prefill_tokens,
                out.makespan_w,
                out.per_replica_completed,
            );

            // Hot-path timing: the full closed-loop drive (dispatch + KV
            // + radix bookkeeping for 48 requests across n replicas).
            let label = format!("router/drive/r{n}/{policy}");
            let timing = bench_with(&label, 10, Duration::from_millis(5), || {
                black_box(drive(n, policy).completed);
            });

            let mut fields = vec![
                ("scenario", json_str(&policy.to_string())),
                ("source", json_str("bench")),
                ("replicas", n.to_string()),
                ("requests", REQUESTS.to_string()),
                ("completed", out.completed.to_string()),
                ("prefill_tokens", out.prefill_tokens.to_string()),
                ("cached_prefill_tokens", out.cached_prefill_tokens.to_string()),
                ("latency_p50_w", pct(&lat, 0.5).to_string()),
                ("latency_p95_w", pct(&lat, 0.95).to_string()),
                ("warm_latency_p95_w", pct(&warm, 0.95).to_string()),
                ("makespan_w", out.makespan_w.to_string()),
                ("tokens_generated", out.tokens_generated.to_string()),
                ("min_replica_completed", min_completed.to_string()),
            ];
            fields.extend(timing.json_fields());
            records.push(json_object(&fields));

            if policy == DispatchPolicy::PrefixAffinity && n >= 2 {
                assert!(
                    min_completed > 0,
                    "replicas {n}: prefix affinity starved a replica"
                );
            }
        }
        // Prefill totals are placement-invariant (every prompt prefills
        // exactly once), so cached-token counts compare hit rates.
        assert!(
            prefill_by_policy.iter().all(|&p| p == prefill_by_policy[0]),
            "replicas {n}: prefill totals diverged {prefill_by_policy:?}"
        );
        // The acceptance bar: at 2+ replicas affinity routing must beat
        // least-loaded on cache reuse (the committed snapshot records the
        // separation).
        if n >= 2 {
            assert!(
                cached_by_policy[2] > cached_by_policy[1],
                "replicas {n}: affinity cached {} <= least-loaded {}",
                cached_by_policy[2],
                cached_by_policy[1],
            );
        }
    }

    let out = std::env::var("BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_router.json".to_string());
    let path = std::path::PathBuf::from(out);
    let config = [
        ("sessions", SESSIONS.to_string()),
        ("turns", TURNS.to_string()),
        ("num_sys", NUM_SYS.to_string()),
        ("max_new", MAX_NEW.to_string()),
    ];
    write_bench_report(&path, "router", "rust-bench", &config, &records)
        .expect("writing report");
    println!("\nwrote {} ({} records)", path.display(), records.len());
}
