//! End-to-end decode-step benchmarks through PJRT: the fused
//! decode+FlashSampling artifact vs the baseline decode+multinomial
//! artifact, and the standalone LM-head kernels — the measured counterpart
//! of the paper's Table 4 comparison on this testbed.
//!
//! Requires `make artifacts`; prints a SKIP note otherwise.

use flashsampling::benchutil::{bench_slow, black_box};
use flashsampling::coordinator::{Engine, EngineConfig, Request, SamplingParams};
use flashsampling::runtime::{Runtime, Tensor};
use flashsampling::sampling::{Key, SamplerSpec};

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP: artifacts/ missing — run `make artifacts` first");
        return;
    }
    println!("## e2e_decode — PJRT artifact timings (CPU backend)\n");
    let rt = Runtime::new(&dir).unwrap();
    let key = Key::from_seed(7);

    // Standalone LM-head kernels: fused vs baselines at each bench shape.
    for spec in rt.manifest().by_kind("flash_sample") {
        let (b, d, v) = (
            spec.meta_usize("B").unwrap(),
            spec.meta_usize("D").unwrap(),
            spec.meta_usize("V").unwrap(),
        );
        let tag = format!("b{b}_d{d}_v{v}");
        let h = Tensor::F32(vec![0.1; b * d], vec![b, d]);
        let w = Tensor::F32(vec![0.01; v * d], vec![v, d]);
        let inputs = [h, w, Tensor::seed(key), Tensor::scalar_u32(0),
                      Tensor::F32(vec![1.0; b], vec![b])];
        for kind in ["flash_sample", "baseline_multinomial", "baseline_gumbel"] {
            let name = format!("{kind}_{tag}");
            if rt.manifest().find(&name).is_err() {
                continue;
            }
            rt.run(&name, &inputs).unwrap(); // compile+warm
            bench_slow(&format!("lmhead/{name}"), || {
                black_box(rt.run(&name, &inputs).unwrap());
            });
        }
    }

    // Whole serving decode steps: fused vs baseline engine.
    for sampler in [SamplerSpec::default(), SamplerSpec::Multinomial] {
        let baseline = sampler.uses_baseline_artifact();
        let mut engine =
            Engine::new(&dir, EngineConfig { sampler, ..Default::default() })
                .unwrap();
        for i in 0..8u64 {
            engine
                .submit(Request::new(
                    i,
                    vec![1 + i as i32; 8],
                    SamplingParams {
                        max_new_tokens: 200, // keep decoding through the bench window
                        ..Default::default()
                    },
                ))
                .unwrap();
        }
        // Prefill everything first.
        for _ in 0..2 {
            engine.step().unwrap();
        }
        let label = if baseline { "baseline_multinomial" } else { "flashsampling" };
        bench_slow(&format!("engine_decode_step/b8/{label}"), || {
            black_box(engine.step().unwrap());
        });
    }
}
