//! Tensor-parallel communication benches: the per-step leader cost of the
//! P2P fan-out merge vs assembling an all-gathered logits tensor and
//! running the separate sampler — the structural comparison behind Table 6
//! and Figure 3 (timing on real NVLink is modeled in gpusim).

use flashsampling::benchutil::{bench, black_box};
use flashsampling::sampling::{
    distributed, gumbel, multinomial, philox, Key, Transform,
};

fn main() {
    println!("## tp_fanout — leader-side merge cost vs all-gather sampling\n");
    let key = Key::new(5, 6);
    let t = Transform::default();
    let b = 16usize;
    for v in [32_768usize, 131_072] {
        for n in [2usize, 4, 8] {
            // FlashSampling path: merge n per-rank summaries per row.
            let summaries: Vec<Vec<distributed::ShardSummary>> = (0..b)
                .map(|row| {
                    (0..n)
                        .map(|r| distributed::ShardSummary {
                            rank: r as u32,
                            max_score: (row * 31 + r) as f32 * 0.01,
                            local_sample: (r * v / n) as u32,
                            log_mass: -(r as f32),
                        })
                        .collect()
                })
                .collect();
            bench(&format!("fanout_merge/B={b}/V={v}/tp{n}"), || {
                for row in &summaries {
                    black_box(distributed::merge_pathwise(row));
                }
            });

            // Baseline path: assemble [B, V] from shards + full sampler pass.
            let shard: Vec<f32> = (0..b * v / n)
                .map(|i| philox::uniform_at(key, i as u32, 0, 3, 0))
                .collect();
            bench(&format!("allgather_assemble/B={b}/V={v}/tp{n}"), || {
                let vs = v / n;
                let mut logits = vec![0.0f32; b * v];
                for r in 0..n {
                    for row in 0..b {
                        logits[row * v + r * vs..row * v + (r + 1) * vs]
                            .copy_from_slice(&shard[row * vs..(row + 1) * vs]);
                    }
                }
                black_box(logits.len());
            });
        }
        // Leader sampling over materialized logits (paid only by baselines).
        let logits: Vec<f32> = (0..b * v)
            .map(|i| philox::uniform_at(key, i as u32, 1, 3, 0))
            .collect();
        bench(&format!("leader_gumbel_full/B={b}/V={v}"), || {
            black_box(gumbel::sample_batch(&logits, v, &t, key, 0));
        });
        bench(&format!("leader_multinomial_full/B={b}/V={v}"), || {
            black_box(multinomial::sample_batch(&logits, v, &t, key, 0));
        });
    }
}
