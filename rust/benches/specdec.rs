//! Speculative-decode throughput: tokens/sec vs draft length K and
//! acceptance rate, over the host-side `SpecDecodeLoop` (the logits-space
//! instantiation of the engine's spec path — DESIGN.md §9).
//!
//! Grid: K ∈ {1, 2, 4, 8} × four drafters spanning the acceptance
//! spectrum — the deterministic n-gram suffix drafter, the target itself
//! as drafter (q = p ⇒ acceptance 1), a 60/40 blend of target and an
//! independent head, and a fully independent head (mostly rejected).
//! Each record carries the measured acceptance rate and tokens/step next
//! to the timing, so `BENCH_specdec.json` directly feeds the
//! `gpusim::tpot::SpecDecodeModel` operating points.  A plain sequential
//! decode over the same target is the `drafter: "none"` reference row.
//! Override the output path with the `BENCH_OUT` environment variable.

use std::time::Duration;

use flashsampling::benchutil::{
    bench_with, black_box, json_object, json_str, write_bench_report,
};
use flashsampling::sampling::{Key, Transform};
use flashsampling::specdec::{
    baseline_generate, Blend, DraftModel, HashModel, NGramDraft, RuntimeDraft,
    SpecDecodeLoop, SpecDecodeStats,
};

const VOCAB: usize = 2048;
const MAX_NEW: usize = 64;
const KS: [usize; 4] = [1, 2, 4, 8];
const DRAFTERS: [&str; 4] = ["ngram", "runtime-self", "runtime-blend", "runtime-indep"];

fn target() -> HashModel {
    HashModel::new(VOCAB, 3, 0xBEC5)
}

/// A partly repetitive prompt so the n-gram drafter has suffix matches.
fn prompt() -> Vec<i32> {
    (0..16).map(|i| (i % 5) * 7 + 1).collect()
}

fn make_drafter(kind: &str) -> Box<dyn DraftModel> {
    match kind {
        "ngram" => Box::new(NGramDraft { n: 3, vocab: VOCAB }),
        // The target itself at the target temperature: q == p, accept-all.
        "runtime-self" => {
            Box::new(RuntimeDraft::new(target(), 1.0, Key::new(0xA, 1)))
        }
        // Partial agreement: blend of target and an independent head.
        "runtime-blend" => Box::new(RuntimeDraft::new(
            Blend { a: target(), b: HashModel::new(VOCAB, 3, 0x0DD), w: 0.6 },
            1.0,
            Key::new(0xA, 2),
        )),
        // Independent head: near-zero agreement, residual path dominant.
        _ => Box::new(RuntimeDraft::new(
            HashModel::new(VOCAB, 3, 0x0DD),
            1.0,
            Key::new(0xA, 3),
        )),
    }
}

fn spec_run(kind: &str, k: usize, key: Key, prompt: &[i32]) -> SpecDecodeStats {
    let t = target();
    let mut drafter = make_drafter(kind);
    let mut l = SpecDecodeLoop {
        target: &t,
        drafter: drafter.as_mut(),
        transform: Transform::default(),
        k,
        key,
    };
    let r = l.generate(prompt, MAX_NEW, 0);
    black_box(&r.tokens);
    r.stats
}

fn main() {
    let key = Key::new(0xB1, 0xB2);
    let t = target();
    let transform = Transform::default();
    let prompt = prompt();
    let mut records: Vec<String> = Vec::new();

    println!("## specdec — tokens/sec vs K and acceptance (V={VOCAB}, {MAX_NEW} tokens/run)\n");

    // Reference: plain sequential decode of the same budget.
    let base = bench_with(
        "specdec/none/sequential",
        10,
        Duration::from_millis(5),
        || {
            black_box(baseline_generate(&t, &transform, key, &prompt, MAX_NEW, 0));
        },
    );
    let base_tps = MAX_NEW as f64 / base.median.as_secs_f64();
    let mut fields = vec![
        ("drafter", json_str("none")),
        ("k", "0".to_string()),
        ("vocab", VOCAB.to_string()),
        ("max_new", MAX_NEW.to_string()),
        ("acceptance_rate", "0".to_string()),
        ("tokens_per_step", "1".to_string()),
        ("tokens_per_sec", format!("{base_tps:.1}")),
    ];
    fields.extend(base.json_fields());
    records.push(json_object(&fields));

    for &k in &KS {
        for kind in DRAFTERS {
            // Accounting from one representative run (deterministic).
            let stats = spec_run(kind, k, key, &prompt);
            let label = format!("specdec/{kind}/K={k}");
            let result = bench_with(&label, 10, Duration::from_millis(5), || {
                spec_run(kind, k, key, &prompt);
            });
            let tps = MAX_NEW as f64 / result.median.as_secs_f64();
            let mut fields = vec![
                ("drafter", json_str(kind)),
                ("k", k.to_string()),
                ("vocab", VOCAB.to_string()),
                ("max_new", MAX_NEW.to_string()),
                ("acceptance_rate", format!("{:.4}", stats.acceptance_rate())),
                ("tokens_per_step", format!("{:.3}", stats.tokens_per_step())),
                ("tokens_per_sec", format!("{tps:.1}")),
            ];
            fields.extend(result.json_fields());
            records.push(json_object(&fields));
        }
    }

    let out = std::env::var("BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_specdec.json".to_string());
    let path = std::path::PathBuf::from(out);
    let config = [
        ("vocab", VOCAB.to_string()),
        ("max_new", MAX_NEW.to_string()),
        ("ks", "[1, 2, 4, 8]".to_string()),
    ];
    write_bench_report(&path, "specdec", "rust-bench", &config, &records)
        .expect("writing report");
    println!(
        "\nwrote {} ({} records: {} drafters x {} Ks + 1 baseline)",
        path.display(),
        records.len(),
        DRAFTERS.len(),
        KS.len()
    );
}
