//! Streaming serving front-end (DESIGN.md §11): handle events, typed
//! errors, mid-flight abort accounting, priority scheduling, and the
//! stream/batch identity guarantee.
//!
//! The abort-balance property test is CPU-only and always runs: it
//! drives the REAL scheduler + KV manager (the same `plan` /
//! `BatchAdmission` / `register_with_prefix` / `extend`+`truncate`
//! machinery the engine uses) through randomized workloads and abort
//! schedules — prefill-pending, mid-decode, spec-decode bursts, and
//! prefix-shared tails — and asserts the allocator and the radix-tree
//! refcounts balance to zero leaks.  The engine-level suites are
//! artifact-gated like the other integration tests.

use flashsampling::coordinator::scheduler::{plan, Plan, SchedulerConfig};
use flashsampling::coordinator::{
    Engine, EngineConfig, EngineError, FinishReason, Priority, Request,
    RequestHandle, RequestOutput, SamplingParams, Sequence,
};
use flashsampling::kvcache::{KvCacheConfig, KvCacheManager};
use flashsampling::prefixcache::BlockKv;
use flashsampling::router::{sim_router, DispatchPolicy, SimReplicaConfig};
use flashsampling::sampling::SamplerSpec;
use flashsampling::testutil;
use flashsampling::trace::TraceLevel;
use flashsampling::workload::{LengthDist, SharedPrefix, WorkloadGen};

// ---------------------------------------------------------------------
// CPU-only: abort-balance property test over the real scheduler + KV
// manager (no artifacts needed).
// ---------------------------------------------------------------------

#[test]
fn prop_any_abort_schedule_leaves_the_pool_balanced() {
    testutil::cases(48, 0xAB07, |g| {
        // Prompt pool with shared prefixes (2 "system prompts" of 8
        // tokens = 2 full blocks at block_size 4) so aborts hit
        // prefix-shared tails and attached chains.
        let prompts: Vec<Vec<i32>> = (0..6)
            .map(|p| {
                let sys = (p % 2) as i32 * 1000;
                let len = 9 + 2 * p; // 9..19 tokens, > 2 blocks
                (0..len as i32)
                    .map(|i| if i < 8 { sys + i } else { sys + 100 * p as i32 + i })
                    .collect()
            })
            .collect();
        const TOTAL: usize = 96;
        let mut kv = KvCacheManager::new(KvCacheConfig {
            block_size: 4,
            num_blocks: TOTAL,
            prefix_caching: true,
        });
        let spec_burst = g.usize_in(0, 4); // 0 = plain decode
        let sched = SchedulerConfig {
            decode_buckets: vec![1, 2, 4, 8],
            prefill_t_buckets: vec![16, 64],
            prefill_b: 4,
            max_concurrency: 8,
            max_tokens_per_step: spec_burst + 1,
            aging_steps: g.usize_in(0, 16) as u64,
            prefill_chunk_tokens: 0,
            chunk_interleave: false,
        };
        let mut waiting: Vec<Sequence> = (0..g.usize_in(4, 14) as u64)
            .map(|i| {
                let mut r = Request::new(
                    i,
                    g.choose(&prompts).clone(),
                    SamplingParams {
                        max_new_tokens: g.usize_in(1, 10),
                        ..Default::default()
                    },
                );
                r.priority =
                    *g.choose(&[Priority::Low, Priority::Normal, Priority::High]);
                Sequence::new(r)
            })
            .collect();
        let mut running: Vec<Sequence> = Vec::new();
        let mut step = 0u64;
        loop {
            step += 1;
            assert!(step < 10_000, "sim stalled");
            // Random mid-flight abort: prefill-pending (waiting, no KV
            // yet) or mid-decode / prefix-shared (running, full release).
            if g.bool(0.25) && !(waiting.is_empty() && running.is_empty()) {
                if !waiting.is_empty() && (running.is_empty() || g.bool(0.5)) {
                    let idx = g.usize_in(0, waiting.len() - 1);
                    waiting.remove(idx);
                } else if !running.is_empty() {
                    let idx = g.usize_in(0, running.len() - 1);
                    let s = running.remove(idx);
                    kv.release(s.id).unwrap();
                }
            }
            let mut admission = kv.batch_admission();
            let p = plan(
                &sched,
                &waiting,
                &running,
                |s, burst| admission.admit(&kv, &s.prompt, burst),
                |s| kv.cached_prefix_tokens(&s.prompt),
                step,
            );
            match p {
                Plan::ChunkPrefill { .. } => {
                    unreachable!("chunking disabled in this schedule")
                }
                Plan::Prefill { seq_ids, .. } => {
                    // Mirror Engine::do_prefill: register+attach all rows,
                    // then publish, then first token + append/release.
                    // Engine backstop mirrored too: if the pool raced
                    // below the plan's estimate (shared evictable
                    // headroom), the victim re-queues at the front
                    // instead of failing.
                    let mut batch: Vec<Sequence> = Vec::new();
                    let mut requeue: Vec<Sequence> = Vec::new();
                    for id in &seq_ids {
                        let idx = waiting
                            .iter()
                            .position(|s| s.id == *id)
                            .expect("planned sequence vanished");
                        let s = waiting.remove(idx);
                        match kv.register_with_prefix(s.id, &s.prompt) {
                            Ok(_) => batch.push(s),
                            Err(_) => requeue.push(s),
                        }
                    }
                    let all_failed = batch.is_empty() && !requeue.is_empty();
                    for s in requeue.into_iter().rev() {
                        waiting.insert(0, s);
                    }
                    if all_failed {
                        // No registration landed: drop the head so the
                        // randomized sim always makes progress (a pure
                        // reject — nothing was allocated, nothing leaks).
                        waiting.remove(0);
                    }
                    for mut s in batch {
                        kv.insert_prefix(s.id, &s.prompt, |_| BlockKv::default())
                            .unwrap();
                        s.generated.push(0);
                        s.state =
                            flashsampling::coordinator::request::SeqState::Running;
                        if s.generated.len() >= s.params.max_new_tokens
                            || !kv.append_token(s.id).unwrap()
                        {
                            kv.release(s.id).unwrap(); // finished or preempted
                        } else {
                            running.push(s);
                        }
                    }
                }
                Plan::Decode { seq_ids, .. } => {
                    let mut finished: Vec<usize> = Vec::new();
                    for id in &seq_ids {
                        let ri = running
                            .iter()
                            .position(|s| s.id == *id)
                            .expect("planned sequence vanished");
                        let s = &mut running[ri];
                        // Spec-decode reservation protocol: optimistic
                        // extend, emit 1..=granted+1, truncate or append
                        // (exactly Engine::do_spec_decode's rollback).
                        let ctx_before = s.prompt.len() + s.generated.len();
                        let granted = kv.extend(s.id, spec_burst).unwrap();
                        let budget_rem =
                            s.params.max_new_tokens - s.generated.len();
                        let emitted =
                            g.usize_in(1, granted + 1).min(budget_rem);
                        for _ in 0..emitted {
                            s.generated.push(0);
                        }
                        let final_len = ctx_before + emitted;
                        let reserved_len = ctx_before + granted;
                        let mut fin =
                            s.generated.len() >= s.params.max_new_tokens;
                        if final_len < reserved_len {
                            kv.truncate(s.id, final_len).unwrap();
                        } else if final_len > reserved_len
                            && !fin
                            && !kv.append_token(s.id).unwrap()
                        {
                            fin = true; // preempted
                        }
                        if fin {
                            finished.push(ri);
                        }
                    }
                    finished.sort_unstable_by(|a, b| b.cmp(a));
                    for ri in finished {
                        let s = running.remove(ri);
                        kv.release(s.id).unwrap();
                    }
                }
                Plan::Idle => {
                    // Never-admittable head => reject (run_to_completion's
                    // backstop); Idle with nothing waiting => done.
                    if waiting.is_empty() {
                        break;
                    }
                    waiting.remove(0);
                }
            }
            assert!(
                kv.free_blocks() + kv.prefix_cached_blocks() <= TOTAL,
                "over-committed pool"
            );
            if waiting.is_empty() && running.is_empty() {
                break;
            }
        }
        // Quiescent balance: zero leaked blocks, zero dangling refs, and
        // draining the cache returns the pool to pristine.
        assert_eq!(kv.unaccounted_blocks(), 0, "leaked blocks after aborts");
        assert_eq!(kv.prefix_attached_refs(), 0, "dangling radix refs");
        kv.clear_prefix_cache();
        assert_eq!(kv.free_blocks(), TOTAL, "cache held phantom refs");
    });
}

#[test]
fn prop_chunked_windows_and_swap_preempts_stay_balanced() {
    // DESIGN.md §12 companion to the abort-balance property above: the
    // same real scheduler + KV manager, now with chunked prefill windows
    // (partially prefilled heads OWN registered KV while still in the
    // waiting queue) and a swap tier (preempted victims hold a ledger
    // entry and keep their prefix-attached blocks pinned).  Randomized
    // aborts hit every lifecycle phase — mid-chunk, mid-decode, and
    // swapped-out — and the pool, the radix refcounts, and the swap
    // ledger must all balance to zero at quiescence.
    testutil::cases(32, 0xC4A9, |g| {
        let prompts: Vec<Vec<i32>> = (0..6)
            .map(|p| {
                let sys = (p % 2) as i32 * 1000;
                let len = 9 + 2 * p; // 9..19 tokens, > 2 blocks
                (0..len as i32)
                    .map(|i| if i < 8 { sys + i } else { sys + 100 * p as i32 + i })
                    .collect()
            })
            .collect();
        const TOTAL: usize = 96;
        let mut kv = KvCacheManager::new(KvCacheConfig {
            block_size: 4,
            num_blocks: TOTAL,
            prefix_caching: true,
        });
        kv.set_swap_capacity(g.usize_in(8, 32));
        let chunk = g.usize_in(2, 8);
        let sched = SchedulerConfig {
            decode_buckets: vec![1, 2, 4, 8],
            prefill_t_buckets: vec![16, 64],
            prefill_b: 4,
            max_concurrency: 8,
            max_tokens_per_step: 1,
            aging_steps: 0,
            prefill_chunk_tokens: chunk,
            chunk_interleave: g.bool(0.5),
        };
        let mut waiting: Vec<Sequence> = (0..g.usize_in(4, 12) as u64)
            .map(|i| {
                Sequence::new(Request::new(
                    i,
                    g.choose(&prompts).clone(),
                    SamplingParams {
                        max_new_tokens: g.usize_in(1, 8),
                        ..Default::default()
                    },
                ))
            })
            .collect();
        let mut running: Vec<Sequence> = Vec::new();
        let mut swapped: Vec<Sequence> = Vec::new();
        let mut step = 0u64;
        loop {
            step += 1;
            assert!(step < 10_000, "sim stalled");
            // Swap-in mirror: resume the FCFS head when the pool allows.
            if !swapped.is_empty() && running.len() < sched.max_concurrency {
                let id = swapped[0].id;
                if kv.swap_in(id).unwrap().is_some() {
                    running.push(swapped.remove(0));
                }
            }
            // Random mid-flight abort across every phase — including a
            // partially prefilled (chunk-registered) head, which owns KV
            // despite still sitting in the waiting queue.
            if g.bool(0.2) {
                let total = waiting.len() + running.len() + swapped.len();
                if total > 0 {
                    let k = g.usize_in(0, total - 1);
                    if k < waiting.len() {
                        let s = waiting.remove(k);
                        if s.prefilled_tokens > 0 {
                            kv.release(s.id).unwrap();
                        }
                    } else if k < waiting.len() + running.len() {
                        let s = running.remove(k - waiting.len());
                        kv.release(s.id).unwrap();
                    } else {
                        let s =
                            swapped.remove(k - waiting.len() - running.len());
                        // Aborting a swapped victim clears its ledger entry.
                        kv.release(s.id).unwrap();
                    }
                }
            }
            // Random preempt-to-swap of a running victim (its table is
            // consistent between steps, exactly when the engine swaps).
            if !running.is_empty() && g.bool(0.15) {
                let idx = g.usize_in(0, running.len() - 1);
                if kv.swap_out(running[idx].id).unwrap().is_some() {
                    swapped.push(running.remove(idx));
                }
            }
            let mut admission = kv.batch_admission();
            let p = plan(
                &sched,
                &waiting,
                &running,
                |s, burst| admission.admit(&kv, &s.prompt, burst),
                |s| kv.cached_prefix_tokens(&s.prompt),
                step,
            );
            match p {
                Plan::ChunkPrefill { seq_id } => {
                    // Engine::do_chunk_prefill mirror: register on the
                    // first window, advance the window, stay at the front
                    // of the queue.
                    let idx = waiting
                        .iter()
                        .position(|s| s.id == seq_id)
                        .expect("planned head vanished");
                    let mut s = waiting.remove(idx);
                    if s.prefilled_tokens == 0 {
                        match kv.register_with_prefix(s.id, &s.prompt) {
                            Ok(a) => s.prefilled_tokens = a.cached_tokens,
                            Err(_) => {
                                waiting.insert(0, s);
                                continue;
                            }
                        }
                    }
                    let take = chunk.min(
                        s.prompt
                            .len()
                            .saturating_sub(1)
                            .saturating_sub(s.prefilled_tokens),
                    );
                    s.prefilled_tokens += take;
                    waiting.insert(0, s);
                }
                Plan::Prefill { seq_ids, .. } => {
                    let mut batch: Vec<Sequence> = Vec::new();
                    let mut requeue: Vec<Sequence> = Vec::new();
                    for id in &seq_ids {
                        let idx = waiting
                            .iter()
                            .position(|s| s.id == *id)
                            .expect("planned sequence vanished");
                        let s = waiting.remove(idx);
                        // Partial heads already own their registration.
                        if s.prefilled_tokens > 0 {
                            batch.push(s);
                            continue;
                        }
                        match kv.register_with_prefix(s.id, &s.prompt) {
                            Ok(_) => batch.push(s),
                            Err(_) => requeue.push(s),
                        }
                    }
                    let all_failed = batch.is_empty() && !requeue.is_empty();
                    for s in requeue.into_iter().rev() {
                        waiting.insert(0, s);
                    }
                    if all_failed {
                        let s = waiting.remove(0);
                        if s.prefilled_tokens > 0 {
                            kv.release(s.id).unwrap();
                        }
                    }
                    for mut s in batch {
                        kv.insert_prefix(s.id, &s.prompt, |_| BlockKv::default())
                            .unwrap();
                        s.generated.push(0);
                        s.state =
                            flashsampling::coordinator::request::SeqState::Running;
                        if s.generated.len() >= s.params.max_new_tokens
                            || !kv.append_token(s.id).unwrap()
                        {
                            kv.release(s.id).unwrap();
                        } else {
                            running.push(s);
                        }
                    }
                }
                Plan::Decode { seq_ids, .. } => {
                    let mut finished: Vec<usize> = Vec::new();
                    for id in &seq_ids {
                        let ri = running
                            .iter()
                            .position(|s| s.id == *id)
                            .expect("planned sequence vanished");
                        let s = &mut running[ri];
                        s.generated.push(0);
                        if s.generated.len() >= s.params.max_new_tokens
                            || !kv.append_token(s.id).unwrap()
                        {
                            finished.push(ri);
                        }
                    }
                    finished.sort_unstable_by(|a, b| b.cmp(a));
                    for ri in finished {
                        let s = running.remove(ri);
                        kv.release(s.id).unwrap();
                    }
                }
                Plan::Idle => {
                    if !waiting.is_empty() {
                        // A fresh unadmittable head mirrors
                        // reject_unschedulable.  (A partial head never
                        // idles: the deferred-window fallback always
                        // chunks it, so the else-branch no-op is purely
                        // defensive.)
                        if waiting[0].prefilled_tokens == 0 {
                            waiting.remove(0);
                        }
                    } else if running.is_empty() && !swapped.is_empty() {
                        // Engine's swap-abandon livelock guard.
                        let s = swapped.remove(0);
                        kv.release(s.id).unwrap();
                    } else if running.is_empty() && swapped.is_empty() {
                        break;
                    }
                }
            }
            if waiting.is_empty() && running.is_empty() && swapped.is_empty() {
                break;
            }
        }
        // Quiescent balance across ALL THREE ledgers: the block pool, the
        // radix attachment refs, and the swap ledger.
        assert_eq!(kv.unaccounted_blocks(), 0, "leaked blocks");
        assert_eq!(kv.prefix_attached_refs(), 0, "dangling radix refs");
        assert_eq!(kv.swapped_blocks(), 0, "stranded swap ledger");
        kv.clear_prefix_cache();
        assert_eq!(kv.free_blocks(), TOTAL, "cache held phantom refs");
    });
}

#[test]
fn prop_trace_derived_counters_balance_under_random_aborts() {
    // Satellite to `repro trace-identity`: the flight recorder's derived
    // counters must stay in lockstep with `ServingMetrics` under ANY
    // abort schedule, not just the certificate's scripted scenarios.
    // Randomized mid-flight aborts across 2 replicas sharing session
    // prefixes under prefix-affinity — at quiescence every replica's
    // trace re-derives its own metrics, every submission is dispatched
    // exactly once and ends in exactly one finish, and the KV pool and
    // radix refcounts balance to zero leaks.
    testutil::cases(24, 0x7AACE, |g| {
        let mut r = sim_router(
            2,
            DispatchPolicy::PrefixAffinity,
            SimReplicaConfig {
                trace_level: TraceLevel::Lifecycle,
                ..Default::default()
            },
        );
        let sys: Vec<i32> = (0..32).map(|j| j * 13 % 211).collect();
        let n = g.usize_in(6, 12) as u64;
        for id in 0..n {
            let mut prompt = sys.clone();
            prompt
                .extend((0..g.usize_in(4, 24)).map(|j| id as i32 * 59 + j as i32));
            r.submit(Request::new(
                id,
                prompt,
                SamplingParams {
                    max_new_tokens: g.usize_in(1, 8),
                    ..Default::default()
                },
            ))
            .unwrap();
        }
        let mut idle = 0;
        while r.pending() > 0 {
            // Random mid-flight abort of any still-live request: hits
            // prefill-pending (waiting) and mid-decode phases alike.
            if g.bool(0.3) {
                let id = g.usize_in(0, n as usize - 1) as u64;
                if r.owner_of(id).is_some() {
                    r.abort(id).unwrap();
                }
            }
            if r.step().unwrap().is_empty() {
                idle += 1;
                if idle > 8 && r.reject_unschedulable().is_some() {
                    idle = 0;
                    continue;
                }
                assert!(idle < 64, "sim livelock");
            } else {
                idle = 0;
            }
        }
        let mut finishes = 0u64;
        let mut dispatches = 0u64;
        for e in r.replicas() {
            let d = e.trace.derived();
            let m = &e.metrics;
            assert_eq!(d.tokens, m.tokens_generated, "token count drifted");
            assert_eq!(d.prefill_tokens, m.prefill_tokens);
            assert_eq!(d.cached_prefill_tokens, m.cached_prefill_tokens);
            assert_eq!(d.finishes, m.requests_completed);
            assert_eq!(d.rejects, 0, "pool is oversized — nothing rejects");
            finishes += d.finishes;
            dispatches += d.dispatches;
        }
        assert_eq!(dispatches, n, "each submission dispatched exactly once");
        assert_eq!(finishes, n, "each submission ends in exactly one finish");
        assert_eq!(r.kv_unaccounted_blocks(), 0, "aborts leaked KV blocks");
        assert_eq!(r.prefix_attached_refs(), 0, "dangling radix refs");
    });
}

// ---------------------------------------------------------------------
// Artifact-gated engine suites.
// ---------------------------------------------------------------------

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("NOTE: artifacts/ missing; run `make artifacts`");
        None
    }
}

fn engine(cfg: EngineConfig) -> Option<Engine> {
    artifacts_dir().map(|d| Engine::new(d, cfg).unwrap())
}

/// Mixed-tau shared-prefix requests (the identity workload).
fn mixed_tau_shared_prefix(vocab: usize, n: usize) -> Vec<Request> {
    let mut g = WorkloadGen::new(0x51D3, 1000.0, vocab);
    g.prefix_mode = Some(SharedPrefix {
        num_prefixes: 2,
        prefix_len: 32,
        users: 4,
        turn_len: LengthDist::Fixed(4),
    });
    g.output_len = LengthDist::Uniform(3, 8);
    g.temperature_choices = vec![0.5, 1.0, 2.0];
    g.generate(n)
        .into_iter()
        .map(|s| {
            Request::new(
                s.id,
                s.prompt,
                SamplingParams {
                    temperature: s.temperature,
                    max_new_tokens: s.max_new_tokens,
                    ..Default::default()
                },
            )
        })
        .collect()
}

#[test]
fn handle_streams_equal_batch_output_token_for_token() {
    // THE identity guarantee: the handle API's concatenated streams must
    // equal the legacy batch path's completions, token for token, on a
    // mixed-tau shared-prefix workload (same seed => same Philox
    // coordinates).
    let Some(mut batch) = engine(EngineConfig::default()) else { return };
    let vocab = batch.runtime().manifest().model.vocab;
    for r in mixed_tau_shared_prefix(vocab, 16) {
        batch.submit(r).unwrap();
    }
    let mut done = batch.run_to_completion().unwrap();
    done.sort_by_key(|c| c.id);
    assert_eq!(done.len(), 16);

    let mut stream = engine(EngineConfig::default()).unwrap();
    let handles: Vec<RequestHandle> = mixed_tau_shared_prefix(vocab, 16)
        .into_iter()
        .map(|r| stream.submit(r).unwrap())
        .collect();
    while stream.pending() > 0 {
        if stream.step().unwrap().is_empty() {
            // Same no-progress backstop as run_to_completion: a stuck
            // head becomes a Rejected terminal event instead of a hang.
            let _ = stream.reject_unschedulable();
        }
    }
    let mut streamed: Vec<(u64, Vec<i32>)> = handles
        .iter()
        .map(|h| {
            let evs = h.drain();
            // Terminal event is last and carries the finish reason.
            assert!(evs.last().unwrap().finish.is_some());
            let toks: Vec<i32> = evs.iter().filter_map(|e| e.token).collect();
            // The handle's completion matches its own stream.
            assert_eq!(h.completion().unwrap().tokens, toks);
            (h.id(), toks)
        })
        .collect();
    streamed.sort_by_key(|(id, _)| *id);
    let batch_tokens: Vec<(u64, Vec<i32>)> =
        done.into_iter().map(|c| (c.id, c.tokens)).collect();
    assert_eq!(
        batch_tokens, streamed,
        "handle streams diverged from the batch path"
    );
}

#[test]
fn per_token_events_carry_step_clock_timing() {
    let Some(mut e) = engine(EngineConfig::default()) else { return };
    let h = e
        .submit(Request::new(
            1,
            vec![3, 14, 15, 9],
            SamplingParams { max_new_tokens: 5, ..Default::default() },
        ))
        .unwrap();
    assert!(!h.is_finished());
    e.run_to_completion().unwrap();
    assert!(h.is_finished());
    let evs: Vec<RequestOutput> = h.drain();
    assert_eq!(evs.len(), 6); // 5 tokens + terminal
    for (i, ev) in evs[..5].iter().enumerate() {
        assert_eq!(ev.request_id, 1);
        assert_eq!(ev.index, i);
        assert_eq!(ev.text_len, i + 1);
        assert!(ev.token.is_some());
        assert!(ev.finish.is_none());
        assert_eq!(ev.ttft_steps.is_some(), i == 0, "ttft only on first");
        assert_eq!(ev.inter_token_steps.is_some(), i > 0);
        assert!(ev.step >= 1, "clock ticks before planning");
    }
    assert!(evs[0].ttft_steps.unwrap() >= 1);
    // Steps are monotone over one request's stream.
    for w in evs[..5].windows(2) {
        assert!(w[1].step > w[0].step, "one token per ordinary decode step");
    }
    let terminal = &evs[5];
    assert_eq!(terminal.token, None);
    assert_eq!(terminal.finish, Some(FinishReason::MaxTokens));
    assert_eq!(terminal.text_len, 5);
    assert_eq!(h.finish_reason(), Some(FinishReason::MaxTokens));
    assert_eq!(h.completion().unwrap().tokens.len(), 5);
    assert!(e.clock() >= 5);
}

#[test]
fn typed_errors_at_the_public_boundary() {
    let Some(mut e) = engine(EngineConfig::default()) else { return };
    let ok = |id: u64| {
        Request::new(
            id,
            vec![1, 2, 3],
            SamplingParams { max_new_tokens: 2, ..Default::default() },
        )
    };
    // Duplicate live id is a typed, pre-scheduler error.
    e.submit(ok(1)).unwrap();
    assert!(matches!(
        e.submit(ok(1)),
        Err(EngineError::DuplicateRequestId { id: 1 })
    ));
    // Unsupported params.
    let mut bad = ok(2);
    bad.params.top_p = Some(0.9);
    assert!(matches!(
        e.submit(bad),
        Err(EngineError::UnsupportedParams { id: 2, .. })
    ));
    // Admission-impossible prompts.
    assert!(matches!(
        e.submit(Request::new(3, vec![], Default::default())),
        Err(EngineError::AdmissionRejected { id: 3, .. })
    ));
    assert!(matches!(
        e.submit(Request::new(4, vec![1; 4096], Default::default())),
        Err(EngineError::AdmissionRejected { id: 4, .. })
    ));
    // Unknown abort target.
    assert!(matches!(
        e.abort(99),
        Err(EngineError::UnknownRequest { id: 99 })
    ));
    // Failed submits left no stream behind: finishing request 1 frees its
    // id for reuse.
    e.run_to_completion().unwrap();
    e.submit(ok(1)).unwrap();
    e.run_to_completion().unwrap();
}

#[test]
fn abort_releases_kv_and_prefix_refs_mid_flight() {
    let Some(mut e) = engine(EngineConfig::default()) else { return };
    let vocab = e.runtime().manifest().model.vocab;
    let mut handles: Vec<RequestHandle> = Vec::new();
    for mut r in mixed_tau_shared_prefix(vocab, 8) {
        r.params.max_new_tokens = 12; // long enough to abort mid-decode
        handles.push(e.submit(r).unwrap());
    }
    // One prefill step: some requests now run (their handles have a
    // token), the rest still wait.
    e.step().unwrap();
    let mut running_events: Vec<(u64, usize)> = Vec::new(); // (id, tokens so far)
    let mut waiting_ids: Vec<u64> = Vec::new();
    for h in &handles {
        let n = h.drain().iter().filter(|ev| ev.token.is_some()).count();
        if n > 0 {
            running_events.push((h.id(), n));
        } else {
            waiting_ids.push(h.id());
        }
    }
    assert!(!running_events.is_empty(), "prefill produced no tokens");
    assert!(!waiting_ids.is_empty(), "everything prefilled at once");

    // Abort one prefill-pending request: no KV was registered.
    let w = waiting_ids[0];
    let c = e.abort(w).unwrap();
    assert_eq!(c.finish, FinishReason::Aborted);
    assert!(c.tokens.is_empty());

    // Decode a couple of steps, then abort one running request mid-decode.
    e.step().unwrap();
    e.step().unwrap();
    let r = running_events[0].0;
    let c = e.abort(r).unwrap();
    assert_eq!(c.finish, FinishReason::Aborted);
    assert!(!c.tokens.is_empty(), "mid-decode abort keeps partial tokens");
    // Double-abort is a typed error.
    assert!(matches!(e.abort(r), Err(EngineError::UnknownRequest { .. })));

    // Aborted handles got their terminal events.
    for h in &handles {
        if h.id() == w || h.id() == r {
            assert_eq!(h.finish_reason(), Some(FinishReason::Aborted));
            let evs = h.drain();
            assert_eq!(evs.last().unwrap().finish, Some(FinishReason::Aborted));
        }
    }

    // Everyone else still completes, and the pool balances to zero leaks
    // (all resident blocks are prefix-cache-held).
    let done = e.run_to_completion().unwrap();
    assert_eq!(done.len(), 6);
    assert_eq!(e.pending(), 0);
    assert_eq!(e.kv_unaccounted_blocks(), 0, "abort leaked KV blocks");
    assert_eq!(e.metrics.counters.get("aborted").copied(), Some(2));
}

#[test]
fn abort_during_spec_decode_burst_stays_balanced() {
    // Spec decode reserves draft blocks optimistically; aborting between
    // steps must leave no reservation behind.
    let Some(mut e) = engine(EngineConfig {
        sampler: SamplerSpec::SpecDecode { k: 4, ngram: 3 },
        ..Default::default()
    }) else {
        return;
    };
    for i in 0..4u64 {
        let p = 2 + i as i32;
        e.submit(Request::new(
            i,
            vec![p, 3, p, 3, p],
            SamplingParams { max_new_tokens: 16, ..Default::default() },
        ))
        .unwrap();
    }
    e.step().unwrap(); // prefill
    e.step().unwrap(); // one spec-decode burst
    e.abort(1).unwrap();
    e.abort(3).unwrap();
    let done = e.run_to_completion().unwrap();
    assert_eq!(done.len(), 2);
    assert_eq!(e.kv_unaccounted_blocks(), 0, "spec abort leaked blocks");
}

#[test]
fn high_priority_overtakes_under_load() {
    // Concurrency 2 forces queueing: a high-priority request submitted
    // LAST must reach its first token no later than the normal-priority
    // requests queued ahead of it.
    let Some(mut e) = engine(EngineConfig {
        max_concurrency: 2,
        ..Default::default()
    }) else {
        return;
    };
    let req = |id: u64, prio: Priority| {
        let mut r = Request::new(
            id,
            vec![1 + id as i32; 4],
            SamplingParams { max_new_tokens: 4, ..Default::default() },
        );
        r.priority = prio;
        r
    };
    let mut handles = Vec::new();
    for i in 0..4u64 {
        handles.push(e.submit(req(i, Priority::Normal)).unwrap());
    }
    let high = e.submit(req(99, Priority::High)).unwrap();
    e.run_to_completion().unwrap();
    let first_step = |h: &RequestHandle| {
        h.drain()
            .iter()
            .find(|ev| ev.token.is_some())
            .expect("no tokens streamed")
            .step
    };
    let high_step = first_step(&high);
    // The two head-of-line normals prefill first (FCFS within the first
    // wave), but the high-priority request beats every later normal.
    assert!(
        high_step <= first_step(&handles[2]) && high_step <= first_step(&handles[3]),
        "high priority failed to overtake the queue"
    );
}
