//! Cross-layer integration: the AOT artifacts (L1 Pallas kernel + L2 JAX
//! graphs, compiled through PJRT) against the native Rust samplers.
//!
//! The load-bearing claim: because every layer draws Gumbel noise from the
//! same position-indexed Philox streams, the fused XLA kernel and the Rust
//! reference must produce *identical* samples (pathwise exactness through
//! the whole stack) — not merely the same distribution.
//!
//! Requires `make artifacts`; tests exit early (pass) with a note if the
//! artifacts directory is missing so `cargo test` works pre-build.

use flashsampling::runtime::{Runtime, Tensor};
use flashsampling::sampling::{
    self, distributed, gumbel, multinomial, philox::Key, Transform,
};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("NOTE: artifacts/ missing; run `make artifacts` for integration tests");
        None
    }
}

/// Deterministic pseudo-input generator (Philox-driven, like the kernels).
fn randn(n: usize, seed: u64, scale: f32) -> Vec<f32> {
    let key = Key::from_seed(seed);
    (0..n)
        .map(|i| {
            // Box-Muller-ish: sum of 4 uniforms, centered (plenty for tests)
            let s: f32 = (0..4)
                .map(|j| sampling::philox::uniform_at(key, i as u32, j, 3, 1))
                .sum();
            (s - 2.0) * scale * 1.7320508 // var(sum4 U) = 1/3
        })
        .collect()
}

/// Row-major f32 matmul: H [b,d] @ W^T [v,d] -> [b,v].
fn matmul_bt(h: &[f32], w: &[f32], b: usize, d: usize, v: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; b * v];
    for bi in 0..b {
        for vi in 0..v {
            let mut acc = 0.0f32;
            for di in 0..d {
                acc += h[bi * d + di] * w[vi * d + di];
            }
            y[bi * v + vi] = acc;
        }
    }
    y
}

const SEED: Key = Key { lo: 0x1234, hi: 0xABCD };

#[test]
fn flash_sample_artifact_matches_rust_gumbel_pathwise() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let (b, d, v) = (4usize, 256usize, 2048usize);
    let h = randn(b * d, 1, 0.5);
    let w = randn(v * d, 2, 0.05);

    let out = rt
        .run(
            "flash_sample_b4_d256_v2048",
            &[
                Tensor::F32(h.clone(), vec![b, d]),
                Tensor::F32(w.clone(), vec![v, d]),
                Tensor::seed(SEED),
                Tensor::scalar_u32(7), // step
                Tensor::F32(vec![1.0; b], vec![b]), // tau: [B] (ABI v2)
            ],
        )
        .unwrap();
    let got = out[0].as_i32().unwrap();

    let logits = matmul_bt(&h, &w, b, d, v);
    let expect = gumbel::sample_batch(&logits, v, &Transform::default(), SEED, 7);
    for (bi, e) in expect.iter().enumerate() {
        assert_eq!(
            got[bi] as u32,
            e.unwrap().index,
            "row {bi}: XLA kernel diverged from Rust Gumbel-Max"
        );
    }
}

#[test]
fn flash_sample_temperature_path_matches() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let (b, d, v) = (4usize, 256usize, 2048usize);
    let h = randn(b * d, 3, 0.5);
    let w = randn(v * d, 4, 0.05);
    for tau in [0.5f32, 2.0] {
        let out = rt
            .run(
                "flash_sample_b4_d256_v2048",
                &[
                    Tensor::F32(h.clone(), vec![b, d]),
                    Tensor::F32(w.clone(), vec![v, d]),
                    Tensor::seed(SEED),
                    Tensor::scalar_u32(0),
                    Tensor::F32(vec![tau; b], vec![b]),
                ],
            )
            .unwrap();
        let got = out[0].as_i32().unwrap().to_vec();
        let logits = matmul_bt(&h, &w, b, d, v);
        let t = Transform::with_temperature(tau);
        let expect = gumbel::sample_batch(&logits, v, &t, SEED, 0);
        for (bi, e) in expect.iter().enumerate() {
            assert_eq!(got[bi] as u32, e.unwrap().index, "tau={tau} row {bi}");
        }
    }
}

#[test]
fn flash_sample_per_row_tau_matches_rust_per_row() {
    // The tau: [B] ABI: every row of one kernel launch samples at its own
    // temperature, pathwise identical to the Rust sampler run row-by-row
    // with the matching transform.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let (b, d, v) = (4usize, 256usize, 2048usize);
    let h = randn(b * d, 15, 0.5);
    let w = randn(v * d, 16, 0.05);
    let taus = [0.5f32, 1.0, 2.0, 4.0];
    let out = rt
        .run(
            "flash_sample_b4_d256_v2048",
            &[
                Tensor::F32(h.clone(), vec![b, d]),
                Tensor::F32(w.clone(), vec![v, d]),
                Tensor::seed(SEED),
                Tensor::scalar_u32(2),
                Tensor::F32(taus.to_vec(), vec![b]),
            ],
        )
        .unwrap();
    let got = out[0].as_i32().unwrap();
    let logits = matmul_bt(&h, &w, b, d, v);
    for (bi, &tau) in taus.iter().enumerate() {
        let t = Transform::with_temperature(tau);
        let expect = gumbel::sample_row(
            &logits[bi * v..(bi + 1) * v],
            &t,
            SEED,
            bi as u32,
            2,
        )
        .unwrap();
        assert_eq!(
            got[bi] as u32, expect.index,
            "row {bi} (tau={tau}): fused kernel diverged from per-row oracle"
        );
    }
}

#[test]
fn flash_sample_logz_matches_rust_lse() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let (b, d, v) = (4usize, 256usize, 2048usize);
    let h = randn(b * d, 5, 0.4);
    let w = randn(v * d, 6, 0.05);
    let out = rt
        .run(
            "flash_sample_logz_b4_d256_v2048",
            &[
                Tensor::F32(h.clone(), vec![b, d]),
                Tensor::F32(w.clone(), vec![v, d]),
                Tensor::seed(SEED),
                Tensor::scalar_u32(0),
                Tensor::F32(vec![1.0; b], vec![b]),
            ],
        )
        .unwrap();
    let logz = out[1].as_f32().unwrap();
    let logits = matmul_bt(&h, &w, b, d, v);
    for bi in 0..b {
        let expect = sampling::log_sum_exp(&logits[bi * v..(bi + 1) * v]);
        assert!(
            (logz[bi] - expect).abs() < 1e-3,
            "row {bi}: logZ {} vs {expect}",
            logz[bi]
        );
    }
}

#[test]
fn baseline_gumbel_artifact_matches_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let (b, d, v) = (4usize, 256usize, 2048usize);
    let h = randn(b * d, 7, 0.5);
    let w = randn(v * d, 8, 0.05);
    let out = rt
        .run(
            "baseline_gumbel_b4_d256_v2048",
            &[
                Tensor::F32(h.clone(), vec![b, d]),
                Tensor::F32(w.clone(), vec![v, d]),
                Tensor::seed(SEED),
                Tensor::scalar_u32(3),
                Tensor::F32(vec![1.0; b], vec![b]),
            ],
        )
        .unwrap();
    let got = out[0].as_i32().unwrap().to_vec();
    let logits = matmul_bt(&h, &w, b, d, v);
    let expect = gumbel::sample_batch(&logits, v, &Transform::default(), SEED, 3);
    for (bi, e) in expect.iter().enumerate() {
        assert_eq!(got[bi] as u32, e.unwrap().index, "row {bi}");
    }
}

#[test]
fn baseline_multinomial_artifact_is_valid_and_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let (b, d, v) = (4usize, 256usize, 2048usize);
    let h = randn(b * d, 9, 0.5);
    let w = randn(v * d, 10, 0.05);
    let inputs = [
        Tensor::F32(h.clone(), vec![b, d]),
        Tensor::F32(w.clone(), vec![v, d]),
        Tensor::seed(SEED),
        Tensor::scalar_u32(0),
        Tensor::F32(vec![1.0; b], vec![b]),
    ];
    let a = rt.run("baseline_multinomial_b4_d256_v2048", &inputs).unwrap();
    let b2 = rt.run("baseline_multinomial_b4_d256_v2048", &inputs).unwrap();
    assert_eq!(a[0], b2[0]);
    let s = a[0].as_i32().unwrap();
    assert!(s.iter().all(|&x| (0..v as i32).contains(&x)));
    // And it agrees with the Rust baseline (same Philox row uniforms); the
    // inverse-CDF search is fp-sensitive at bin boundaries, so allow the
    // indices to differ only where the CDF gap is microscopic: in practice
    // they match exactly on this fixture.
    let logits = matmul_bt(&h, &w, b, d, v);
    let expect =
        multinomial::sample_batch(&logits, v, &Transform::default(), SEED, 0);
    for (bi, e) in expect.iter().enumerate() {
        assert_eq!(s[bi] as u32, e.unwrap(), "row {bi}");
    }
}

#[test]
fn shard_artifacts_merge_to_single_device_sample() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let (b, d, v, n) = (4usize, 256usize, 2048usize, 2usize);
    let h = randn(b * d, 11, 0.5);
    let w = randn(v * d, 12, 0.05);
    let vs = v / n;
    let step = 5u32;

    // Run the per-rank shard kernel for each vocabulary shard.
    let mut per_rank = Vec::new();
    for r in 0..n {
        let w_shard = w[r * vs * d..(r + 1) * vs * d].to_vec();
        let out = rt
            .run(
                "shard_sample_b4_d256_v2048_tp2",
                &[
                    Tensor::F32(h.clone(), vec![b, d]),
                    Tensor::F32(w_shard, vec![vs, d]),
                    Tensor::I32(vec![(r * vs) as i32], vec![1]),
                    Tensor::seed(SEED),
                    Tensor::scalar_u32(step),
                    Tensor::F32(vec![1.0; b], vec![b]),
                ],
            )
            .unwrap();
        per_rank.push((
            out[0].as_f32().unwrap().to_vec(),  // m
            out[1].as_i32().unwrap().to_vec(),  // global idx
            out[2].as_f32().unwrap().to_vec(),  // lmass
        ));
    }

    // Pathwise merge across ranks == monolithic fused sample.
    let whole = rt
        .run(
            "flash_sample_b4_d256_v2048",
            &[
                Tensor::F32(h.clone(), vec![b, d]),
                Tensor::F32(w.clone(), vec![v, d]),
                Tensor::seed(SEED),
                Tensor::scalar_u32(step),
                Tensor::F32(vec![1.0; b], vec![b]),
            ],
        )
        .unwrap();
    let whole = whole[0].as_i32().unwrap();

    for bi in 0..b {
        let summaries: Vec<distributed::ShardSummary> = (0..n)
            .map(|r| distributed::ShardSummary {
                rank: r as u32,
                max_score: per_rank[r].0[bi],
                local_sample: per_rank[r].1[bi] as u32,
                log_mass: per_rank[r].2[bi],
            })
            .collect();
        let merged = distributed::merge_pathwise(&summaries).unwrap();
        assert_eq!(
            merged.local_sample, whole[bi] as u32,
            "row {bi}: TP merge != single-device"
        );
        // Shard masses recombine to the full normalizer.
        let lz = distributed::log_z(&summaries);
        let logits = matmul_bt(&h, &w, b, d, v);
        let expect = sampling::log_sum_exp(&logits[bi * v..(bi + 1) * v]);
        assert!((lz - expect).abs() < 1e-3, "row {bi}: logZ {lz} vs {expect}");
    }
}

#[test]
fn logits_store_ablation_artifact_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let (b, d, v) = (4usize, 256usize, 2048usize);
    let h = randn(b * d, 13, 0.5);
    let w = randn(v * d, 14, 0.05);
    let out = rt
        .run(
            "flash_sample_store_b4_d256_v2048",
            &[
                Tensor::F32(h.clone(), vec![b, d]),
                Tensor::F32(w.clone(), vec![v, d]),
                Tensor::seed(SEED),
                Tensor::scalar_u32(0),
                Tensor::F32(vec![1.0; b], vec![b]),
            ],
        )
        .unwrap();
    // Output 0: samples (same as non-store kernel); output 1: [B, V] logits.
    let sample = out[0].as_i32().unwrap().to_vec();
    let logits_stored = out[1].as_f32().unwrap();
    assert_eq!(logits_stored.len(), b * v);
    let logits = matmul_bt(&h, &w, b, d, v);
    for i in 0..b * v {
        assert!(
            (logits_stored[i] - logits[i]).abs() < 2e-2 + 1e-3 * logits[i].abs(),
            "logit {i}: {} vs {}",
            logits_stored[i],
            logits[i]
        );
    }
    let no_store = rt
        .run(
            "flash_sample_b4_d256_v2048",
            &[
                Tensor::F32(h, vec![b, d]),
                Tensor::F32(w, vec![v, d]),
                Tensor::seed(SEED),
                Tensor::scalar_u32(0),
                Tensor::F32(vec![1.0; b], vec![b]),
            ],
        )
        .unwrap();
    assert_eq!(sample, no_store[0].as_i32().unwrap().to_vec());
}

#[test]
fn input_validation_rejects_bad_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let err = rt.run(
        "flash_sample_b4_d256_v2048",
        &[Tensor::zeros_f32(&[4, 128])], // wrong arity + shape
    );
    assert!(err.is_err());
}
