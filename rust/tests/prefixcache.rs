//! Automatic prefix caching — end-to-end exactness and accounting
//! (DESIGN.md §10).
//!
//! The headline test is the acceptance criterion of the subsystem:
//! token-for-token identical engine output (same seeds, same
//! `SamplerSpec`) with prefix caching enabled vs. disabled on a
//! shared-prefix workload — through the REAL AOT artifacts, so the
//! `prefill_cached` suffix path, the restored KV bytes, and the Philox
//! coordinates all get exercised.  Artifact-gated like the other
//! integration suites (no-op with a note until `make artifacts`); the
//! accounting-level on/off identity runs everywhere via
//! `repro prefix-identity` and the unit suites.

use flashsampling::coordinator::{Engine, EngineConfig, Request, SamplingParams};
use flashsampling::workload::{LengthDist, SharedPrefix, WorkloadGen};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("NOTE: artifacts/ missing; run `make artifacts`");
        None
    }
}

fn engine(cfg: EngineConfig) -> Option<Engine> {
    artifacts_dir().map(|d| Engine::new(d, cfg).unwrap())
}

/// 2 system prompts x 4 users, multi-turn, prompts within the t=64
/// prefill bucket — the hit-heavy workload shape.
fn shared_prefix_requests(vocab: usize, n: usize) -> Vec<Request> {
    let mut g = WorkloadGen::new(0x5EED, 1000.0, vocab);
    g.prefix_mode = Some(SharedPrefix {
        num_prefixes: 2,
        prefix_len: 32,
        users: 4,
        turn_len: LengthDist::Fixed(4),
    });
    g.output_len = LengthDist::Uniform(3, 7);
    g.generate(n)
        .into_iter()
        .map(|s| {
            Request::new(
                s.id,
                s.prompt,
                SamplingParams {
                    max_new_tokens: s.max_new_tokens,
                    ..Default::default()
                },
            )
        })
        .collect()
}

#[test]
fn caching_on_off_token_identity_on_shared_prefix_workload() {
    let run = |prefix_caching: bool| -> Option<Vec<(u64, Vec<i32>)>> {
        let mut e = engine(EngineConfig {
            prefix_caching,
            ..Default::default()
        })?;
        let vocab = e.runtime().manifest().model.vocab;
        for r in shared_prefix_requests(vocab, 16) {
            e.submit(r).unwrap();
        }
        let mut done = e.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), 16);
        // The cache-on run must actually hit (multi-turn reuse) and must
        // route through the cached-prefill artifact.
        if prefix_caching {
            let hit = e.metrics.prefix_hit_rate().unwrap();
            assert!(hit >= 0.5, "hit-heavy workload only hit {hit:.3}");
            assert!(
                e.metrics.counters.get("prefill_cached_runs").copied()
                    .unwrap_or(0) > 0,
                "cached-prefill artifact never ran"
            );
            // Refcount balance: every resident block is cache-held
            // (512 = EngineConfig::default().kv_blocks).
            assert_eq!(
                512 - e.kv_free_blocks(),
                e.prefix_cached_blocks(),
                "leaked KV blocks after all releases"
            );
        } else {
            assert_eq!(e.metrics.cached_prefill_tokens, 0);
            assert_eq!(e.prefix_cached_blocks(), 0);
        }
        Some(done.into_iter().map(|c| (c.id, c.tokens)).collect())
    };
    let Some(on) = run(true) else { return };
    let off = run(false).unwrap();
    assert_eq!(
        on, off,
        "prefix caching changed sampled tokens — exactness broken"
    );
}

#[test]
fn repeated_identical_prompts_replay_exactly_and_hit() {
    // The simplest sharing shape: the same prompt submitted repeatedly
    // (one at a time) must hit the cache after the first prefill and
    // still reproduce byte-identical per-request behavior vs a cold
    // engine run of the same schedule with caching off.
    let prompt: Vec<i32> = (0..40).map(|i| (i * 7 + 3) % 512).collect();
    let run = |prefix_caching: bool| -> Option<Vec<Vec<i32>>> {
        let mut e = engine(EngineConfig {
            prefix_caching,
            ..Default::default()
        })?;
        let mut outs = Vec::new();
        for id in 0..3u64 {
            e.submit(Request::new(
                id,
                prompt.clone(),
                SamplingParams { max_new_tokens: 5, ..Default::default() },
            ))
            .unwrap();
            let done = e.run_to_completion().unwrap();
            assert_eq!(done.len(), 1);
            outs.push(done.into_iter().next().unwrap().tokens);
        }
        if prefix_caching {
            // Requests 2 and 3 each reuse 32 of 40 prompt tokens.
            assert_eq!(e.metrics.cached_prefill_tokens, 64);
        }
        Some(outs)
    };
    let Some(on) = run(true) else { return };
    let off = run(false).unwrap();
    assert_eq!(on, off);
}

#[test]
fn eviction_under_kv_pressure_keeps_the_engine_correct() {
    // A small pool forces the cache to give blocks back under pressure;
    // every request must still complete (or be cleanly rejected), and the
    // pool must balance to free + cache-resident == total afterwards.
    let Some(mut e) = engine(EngineConfig {
        kv_blocks: 12,
        kv_block_size: 16,
        prefix_caching: true,
        ..Default::default()
    }) else {
        return;
    };
    let vocab = e.runtime().manifest().model.vocab;
    for r in shared_prefix_requests(vocab, 10) {
        e.submit(r).unwrap();
    }
    let done = e.run_to_completion().unwrap();
    assert_eq!(done.len(), 10);
    assert_eq!(
        12 - e.kv_free_blocks(),
        e.prefix_cached_blocks(),
        "pool out of balance after pressure run"
    );
}
