//! Tensor-parallel integration: rank threads + interconnect + merge
//! against the single-device fused kernel and a native oracle.
//!
//! Requires `make artifacts`.

use flashsampling::runtime::{Runtime, Tensor};
use flashsampling::sampling::philox::{self, Key};
use flashsampling::tp::{Strategy, TpConfig, TpOrchestrator};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("NOTE: artifacts/ missing; run `make artifacts`");
        None
    }
}

fn randn(n: usize, seed: u64, scale: f32) -> Vec<f32> {
    let key = Key::from_seed(seed);
    (0..n)
        .map(|i| {
            let s: f32 = (0..4)
                .map(|j| philox::uniform_at(key, i as u32, j, 3, 1))
                .sum();
            (s - 2.0) * scale * 1.7320508
        })
        .collect()
}

const SEED: u64 = 0xABCD_1234;
const B: usize = 4;
const D: usize = 256;
const V: usize = 2048;

fn orchestrator(n: usize, w: &[f32]) -> Option<TpOrchestrator> {
    let dir = artifacts_dir()?;
    Some(
        TpOrchestrator::new(
            TpConfig {
                artifacts_dir: dir,
                n_ranks: n,
                batch: B,
                d_model: D,
                vocab: V,
                seed: SEED,
            },
            w,
        )
        .unwrap(),
    )
}

#[test]
fn fanout_matches_single_device_kernel() {
    let Some(dir) = artifacts_dir() else { return };
    let w = randn(V * D, 2, 0.05);
    let h = randn(B * D, 1, 0.5);

    // Single-device fused sample through PJRT.
    let rt = Runtime::new(&dir).unwrap();
    let single = rt
        .run(
            "flash_sample_b4_d256_v2048",
            &[
                Tensor::F32(h.clone(), vec![B, D]),
                Tensor::F32(w.clone(), vec![V, D]),
                Tensor::seed(Key::from_seed(SEED)),
                Tensor::scalar_u32(3),
                Tensor::F32(vec![1.0; B], vec![B]),
            ],
        )
        .unwrap();
    let expect = single[0].as_i32().unwrap().to_vec();

    for n in [2usize, 4] {
        let mut orch = orchestrator(n, &w).unwrap();
        let out = orch.step(&h, 3, &[1.0; B], Strategy::P2pFanout).unwrap();
        assert_eq!(out.samples, expect, "TP{n} fan-out != single device");
        assert!(out.log_z.is_some());
        orch.shutdown().unwrap();
    }
}

#[test]
fn allgather_baselines_produce_valid_samples() {
    let w = randn(V * D, 4, 0.05);
    let h = randn(B * D, 3, 0.5);
    let Some(mut orch) = orchestrator(2, &w) else { return };
    for strategy in [Strategy::AllGatherMultinomial, Strategy::AllGatherGumbel] {
        let out = orch.step(&h, 0, &[1.0; B], strategy).unwrap();
        assert_eq!(out.samples.len(), B);
        assert!(out.samples.iter().all(|&s| (0..V as i32).contains(&s)));
    }
    orch.shutdown().unwrap();
}

#[test]
fn allgather_gumbel_matches_fanout_pathwise() {
    // Same Philox streams => the all-gather+GumbelMax baseline and the
    // fan-out merge pick the SAME index (both compute argmax of the same
    // perturbed scores). Distinct code paths, identical samples.
    let w = randn(V * D, 6, 0.05);
    let h = randn(B * D, 5, 0.5);
    let Some(mut orch) = orchestrator(2, &w) else { return };
    let a = orch.step(&h, 7, &[1.0; B], Strategy::P2pFanout).unwrap();
    let b = orch.step(&h, 7, &[1.0; B], Strategy::AllGatherGumbel).unwrap();
    assert_eq!(a.samples, b.samples);
    orch.shutdown().unwrap();
}

#[test]
fn wire_bytes_scale_as_paper_claims() {
    let w = randn(V * D, 8, 0.05);
    let h = randn(B * D, 7, 0.5);
    let Some(mut orch) = orchestrator(4, &w) else { return };

    let fanout = orch.step(&h, 0, &[1.0; B], Strategy::P2pFanout).unwrap();
    let gather = orch.step(&h, 1, &[1.0; B], Strategy::AllGatherGumbel).unwrap();

    // Fan-out: n ranks x B rows x 12 bytes.
    assert_eq!(fanout.wire_bytes, (4 * B * 12) as u64);
    // All-gather: n ranks x B x (V/n) x 4 bytes = B*V*4 total.
    assert_eq!(gather.wire_bytes, (B * V * 4) as u64);
    // The paper's point: the ratio is O(V / n_scalars), huge.
    assert!(gather.wire_bytes > 100 * fanout.wire_bytes);
    orch.shutdown().unwrap();
}

#[test]
fn mixed_tau_fanout_matches_allgather_pathwise() {
    // Per-row tau through the TP path: the rank kernels consume tau: [B],
    // and the leader's all-gather + per-row Gumbel-Max over materialized
    // logits draws from the same Philox streams — identical samples.
    let w = randn(V * D, 14, 0.05);
    let h = randn(B * D, 13, 0.5);
    let taus = [0.5f32, 1.0, 2.0, 4.0];
    let Some(mut orch) = orchestrator(2, &w) else { return };
    let a = orch.step(&h, 9, &taus, Strategy::P2pFanout).unwrap();
    let b = orch.step(&h, 9, &taus, Strategy::AllGatherGumbel).unwrap();
    assert_eq!(a.samples, b.samples);
    // And a batch-size mismatch in the tau vector is a hard error.
    assert!(orch.step(&h, 10, &[1.0; 3], Strategy::P2pFanout).is_err());
    orch.shutdown().unwrap();
}

#[test]
fn mixed_tau_fanout_matches_fused_kernel() {
    // tau: [B] end-to-end: the single-device fused kernel and the TP
    // fan-out merge consume the same per-row temperatures and the same
    // Philox (row, cstep) coordinates — identical samples at every TP
    // degree.  (The uniform-tau version of this is
    // `fanout_matches_single_device_kernel`.)
    let Some(dir) = artifacts_dir() else { return };
    let w = randn(V * D, 16, 0.05);
    let h = randn(B * D, 15, 0.5);
    let taus = [0.5f32, 1.0, 2.0, 4.0];
    let rt = Runtime::new(&dir).unwrap();
    let single = rt
        .run(
            "flash_sample_b4_d256_v2048",
            &[
                Tensor::F32(h.clone(), vec![B, D]),
                Tensor::F32(w.clone(), vec![V, D]),
                Tensor::seed(Key::from_seed(SEED)),
                Tensor::scalar_u32(11),
                Tensor::F32(taus.to_vec(), vec![B]),
            ],
        )
        .unwrap();
    let expect = single[0].as_i32().unwrap().to_vec();
    for n in [2usize, 4] {
        let mut orch = orchestrator(n, &w).unwrap();
        let out = orch.step(&h, 11, &taus, Strategy::P2pFanout).unwrap();
        assert_eq!(out.samples, expect, "TP{n} mixed-tau fan-out != fused");
        orch.shutdown().unwrap();
    }
}

#[test]
fn mixed_tau_allgather_multinomial_is_valid_deterministic_and_rowwise() {
    // The third strategy with tau: [B]: valid samples, same-step
    // determinism, and per-row stream independence — perturbing one
    // row's temperature leaves every other row's draw untouched.
    let w = randn(V * D, 18, 0.05);
    let h = randn(B * D, 17, 0.5);
    let taus = [0.5f32, 1.0, 2.0, 4.0];
    let Some(mut orch) = orchestrator(2, &w) else { return };
    let a = orch.step(&h, 3, &taus, Strategy::AllGatherMultinomial).unwrap();
    assert_eq!(a.samples.len(), B);
    assert!(a.samples.iter().all(|&s| (0..V as i32).contains(&s)));
    let b = orch.step(&h, 3, &taus, Strategy::AllGatherMultinomial).unwrap();
    assert_eq!(a.samples, b.samples, "same step + taus must replay");
    // Row 2 gets a different temperature; rows 0, 1, 3 must not move.
    let perturbed = [0.5f32, 1.0, 7.5, 4.0];
    let c = orch
        .step(&h, 3, &perturbed, Strategy::AllGatherMultinomial)
        .unwrap();
    for row in [0usize, 1, 3] {
        assert_eq!(a.samples[row], c.samples[row], "row {row} perturbed");
    }
    // Tau-vector shape errors are hard errors here too.
    assert!(orch
        .step(&h, 4, &[1.0; B + 1], Strategy::AllGatherMultinomial)
        .is_err());
    orch.shutdown().unwrap();
}

#[test]
fn mixed_tau_is_tp_degree_invariant() {
    // Shard count is invisible in the token stream even with per-row
    // temperatures, for every strategy (the EngineBackend unification
    // leans on exactly this).
    let w = randn(V * D, 20, 0.05);
    let h = randn(B * D, 19, 0.5);
    let taus = [0.25f32, 1.0, 1.5, 3.0];
    let Some(mut o2) = orchestrator(2, &w) else { return };
    let mut o4 = orchestrator(4, &w).unwrap();
    for (step, strategy) in [
        (21u32, Strategy::P2pFanout),
        (22, Strategy::AllGatherMultinomial),
        (23, Strategy::AllGatherGumbel),
    ] {
        let a = o2.step(&h, step, &taus, strategy).unwrap();
        let b = o4.step(&h, step, &taus, strategy).unwrap();
        assert_eq!(a.samples, b.samples, "{strategy:?} varies with TP degree");
    }
    o2.shutdown().unwrap();
    o4.shutdown().unwrap();
}

#[test]
fn steps_are_deterministic_and_fresh() {
    let w = randn(V * D, 10, 0.05);
    let h = randn(B * D, 9, 0.5);
    let Some(mut orch) = orchestrator(2, &w) else { return };
    let a1 = orch.step(&h, 5, &[1.0; B], Strategy::P2pFanout).unwrap();
    let a2 = orch.step(&h, 5, &[1.0; B], Strategy::P2pFanout).unwrap();
    assert_eq!(a1.samples, a2.samples); // same step => same draw
    let b = orch.step(&h, 6, &[1.0; B], Strategy::P2pFanout).unwrap();
    assert_ne!(a1.samples, b.samples); // fresh noise per step
    orch.shutdown().unwrap();
}

#[test]
fn link_stats_accumulate_per_rank() {
    let w = randn(V * D, 12, 0.05);
    let h = randn(B * D, 11, 0.5);
    let Some(mut orch) = orchestrator(2, &w) else { return };
    orch.step(&h, 0, &[1.0; B], Strategy::P2pFanout).unwrap();
    orch.step(&h, 1, &[1.0; B], Strategy::P2pFanout).unwrap();
    let stats = orch.link_stats();
    assert_eq!(stats.len(), 2);
    for s in stats {
        assert_eq!(s.messages, 2);
        assert_eq!(s.bytes, (2 * B * 12) as u64);
    }
    orch.shutdown().unwrap();
}
