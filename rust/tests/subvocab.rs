//! Certified sub-vocabulary decode property suite (DESIGN.md §16).
//!
//! The load-bearing claim: whenever the exactness certificate admits a
//! tile skip, the skipped-tile Gumbel-argmax equals the full-vocabulary
//! argmax **bit-for-bit** — same Philox coordinates, same tie-breaking —
//! and whenever it cannot, the fallback pass makes the sub-vocab head
//! invisible.  CPU-only legs run always (host-side reference sampler);
//! the engine leg is artifact-gated like the other integration suites.
//!
//! CI matrix contract: `FS_TEST_SUBVOCAB` (`0` disables) pins whether the
//! sim/engine legs run with the sub-vocab head on — crossing on/off
//! checks that serving output never depends on the setting (that IS the
//! exactness contract at system level).

use flashsampling::coordinator::{Engine, EngineConfig, Request, SamplingParams};
use flashsampling::router::{EngineBackend, SimReplica, SimReplicaConfig};
use flashsampling::sampling::{philox, Key};
use flashsampling::subvocab::{
    certified_sample, excluded_bound, full_argmax, CandidateSet, TileNorms,
    SUB_TILE_V,
};

/// CI matrix override: sub-vocab head on unless `FS_TEST_SUBVOCAB=0`.
fn subvocab_on() -> bool {
    std::env::var("FS_TEST_SUBVOCAB").map_or(true, |v| v != "0")
}

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("NOTE: artifacts/ missing; run `make artifacts`");
        None
    }
}

/// Skew-structured LM head, identical to the subvocab unit fixture:
/// tile 0 carries hot rows (amplitude `a_i` in [0.45, 0.6] along the
/// all-ones direction plus small noise), later tiles are pure noise.
/// Isotropic rows would never admit a certified skip — Cauchy–Schwarz
/// is loose by ~sqrt(d) for incoherent vectors.
fn toy_head(vocab: usize, d: usize, seed: u64) -> Vec<f32> {
    let key = Key::from_seed(seed);
    let mut w = vec![0.0f32; vocab * d];
    for i in 0..vocab {
        let hot = i < SUB_TILE_V;
        let a =
            0.45 + 0.15 * philox::uniform_at(key, i as u32, d as u32, 5, 0);
        for j in 0..d {
            let n = philox::uniform_at(key, i as u32, j as u32, 5, 0) - 0.5;
            w[i * d + j] = if hot { a + 0.25 * n } else { n };
        }
    }
    w
}

/// Step-varying hidden state: a shared bias `b` in [-0.25, 1.25] along
/// the all-ones direction plus unit-scale noise; steps with `b` near
/// zero force full-vocab fallbacks.
fn toy_hidden(d: usize, seed: u64, step: u32) -> Vec<f32> {
    let key = Key::from_seed(seed);
    let b = 1.5 * philox::uniform_at(key, d as u32, 0, 6, step) - 0.25;
    (0..d)
        .map(|j| b + philox::uniform_at(key, j as u32, 0, 6, step) - 0.5)
        .collect()
}

/// The property in the ISSUE's words: whenever the bound admits skipping,
/// the skipped-tile argmax equals the full-vocab argmax bit-for-bit, at
/// unchanged Philox coordinates.  Randomized over heads, hidden states,
/// steps, rows, temperatures, and candidate budgets; the run must
/// actually admit a healthy number of skips or it certifies nothing.
#[test]
fn admitted_skips_equal_full_argmax_bit_for_bit() {
    let (vocab, d) = (512, 32);
    let mut skips = 0u32;
    let mut fallbacks = 0u32;
    for head_seed in 0..8u64 {
        let w = toy_head(vocab, d, 1000 + head_seed);
        let tn = TileNorms::from_lm_head(&w, vocab, d, SUB_TILE_V);
        let key = Key::from_seed(2000 + head_seed);
        for step in 0..60u32 {
            let h = toy_hidden(d, 3000 + head_seed, step);
            let row = (step % 4) as u32;
            let tau = [0.25f32, 0.5, 1.0][(step % 3) as usize];
            for budget in 1..=3usize {
                let cands: Vec<u32> = (0..budget as u32).collect();
                let draw = certified_sample(
                    &w, vocab, d, &h, tau, &cands, &tn, 0.0, key, row, step,
                );
                let (oracle, best) =
                    full_argmax(&w, vocab, d, &h, tau, key, row, step);
                assert_eq!(
                    draw.token, oracle,
                    "head {head_seed} step {step} row {row} tau {tau} \
                     budget {budget} (fallback={})",
                    draw.fallback
                );
                if draw.fallback {
                    fallbacks += 1;
                } else {
                    skips += 1;
                    // An admitted skip means the candidate winner IS the
                    // global winner — scores must agree bitwise too.
                    assert_eq!(draw.winner_score.to_bits(), best.to_bits());
                    assert!(draw.winner_score > draw.bound);
                }
            }
        }
    }
    assert!(skips > 100, "only {skips} skips admitted — fixture too cold");
    assert!(fallbacks > 0, "slack 0 never fell back — bound suspiciously loose");
}

/// The certificate bound must dominate every excluded row's perturbed
/// score — on ragged vocabularies too (last tile shorter than
/// `SUB_TILE_V`).
#[test]
fn excluded_bound_is_sound_on_ragged_vocab() {
    let (vocab, d) = (450, 16); // 4 tiles, last one ragged
    for trial in 0..6u64 {
        let w = toy_head(vocab, d, 50 + trial);
        let tn = TileNorms::from_lm_head(&w, vocab, d, SUB_TILE_V);
        let key = Key::from_seed(60 + trial);
        for step in 0..10u32 {
            let h = toy_hidden(d, 70 + trial, step);
            let h_norm = h.iter().map(|x| x * x).sum::<f32>().sqrt();
            let included = [(trial % 4) as i32];
            let bound =
                excluded_bound(&tn, &included, h_norm, 0.5, key, 0, step);
            for i in 0..vocab {
                if (i / SUB_TILE_V) as i32 == included[0] {
                    continue;
                }
                let y: f32 = w[i * d..(i + 1) * d]
                    .iter()
                    .zip(&h)
                    .map(|(a, b)| a * b)
                    .sum::<f32>()
                    / 0.5;
                let s = y + philox::gumbel_at(key, i as u32, 0, step);
                assert!(
                    s <= bound,
                    "trial {trial} step {step} row {i}: {s} > bound {bound}"
                );
            }
        }
    }
}

/// Candidate maintenance feeds the certificate: a set mis-primed on cold
/// tiles must fall back early (the certificate refuses — the hot tile is
/// excluded and its norm bound dwarfs any cold winner), then online
/// observations of its own emissions overtake the stale counts and the
/// skip rate climbs, with every draw still equal to the oracle.
#[test]
fn online_candidate_set_warms_up_without_losing_exactness() {
    let (vocab, d) = (512, 32);
    let w = toy_head(vocab, d, 77);
    let tn = TileNorms::from_lm_head(&w, vocab, d, SUB_TILE_V);
    let key = Key::from_seed(78);
    let h = toy_hidden(d, 79, 0);
    let mut cs = CandidateSet::new(vocab, SUB_TILE_V);
    // Stale prompt pinned on cold tiles 2 and 3: until ~step 150 the
    // candidate list is [2, 3] and every step must fall back.
    for _ in 0..150 {
        cs.observe_prompt(&[260, 390]);
    }
    let (mut early_skips, mut late_skips) = (0u32, 0u32);
    for step in 0..400u32 {
        let cands = cs.candidates(2);
        let draw = certified_sample(
            &w, vocab, d, &h, 0.25, &cands, &tn, 0.0, key, 0, step,
        );
        let (oracle, _) = full_argmax(&w, vocab, d, &h, 0.25, key, 0, step);
        assert_eq!(draw.token, oracle, "step {step}");
        cs.observe(draw.token);
        if step < 200 {
            early_skips += !draw.fallback as u32;
        } else {
            late_skips += !draw.fallback as u32;
        }
    }
    assert!(
        late_skips >= early_skips,
        "warm set skips ({late_skips}) fell below cold ({early_skips})"
    );
    assert!(late_skips > 0, "warm candidate set never admitted a skip");
}

/// System-level invariance, the `FS_TEST_SUBVOCAB` matrix leg: a
/// `SimReplica` run with the sub-vocab event model per the env knob
/// produces the exact token streams of a run with it off — the knob may
/// only add trace events and counters, never change output.
#[test]
fn sim_replica_output_is_invariant_under_the_matrix_knob() {
    let run = |subvocab: bool| {
        let mut e = SimReplica::new(SimReplicaConfig {
            subvocab,
            ..Default::default()
        });
        for id in 0..5u64 {
            let prompt: Vec<i32> =
                (0..30).map(|j| (id as i32 * 11 + j) % 101).collect();
            let req = Request::new(
                id,
                prompt,
                SamplingParams { max_new_tokens: 4 + id as usize % 3, ..Default::default() },
            );
            let _ = e.submit(req).unwrap();
        }
        let mut done = Vec::new();
        let mut idle = 0;
        while e.pending() > 0 {
            let step = e.step().unwrap();
            if step.is_empty() {
                idle += 1;
                assert!(idle < 64, "sim livelock");
            } else {
                idle = 0;
            }
            done.extend(step);
        }
        done.sort_by_key(|c| c.id);
        done
    };
    let knob = run(subvocab_on());
    let off = run(false);
    assert_eq!(knob.len(), off.len());
    for (a, b) in knob.iter().zip(&off) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "request {} diverged", a.id);
        assert_eq!(a.finish, b.finish);
    }
}

/// Engine leg (artifact-gated): serving output with the certified
/// sub-vocab head per the matrix knob is bit-identical to the plain
/// engine, and the fallback accounting shows up when the head is active.
#[test]
fn engine_tokens_are_bit_identical_with_subvocab_head() {
    let Some(dir) = artifacts_dir() else { return };
    let run = |subvocab: bool| {
        let mut e = Engine::new(
            &dir,
            EngineConfig { subvocab, ..Default::default() },
        )
        .unwrap();
        let active = e.subvocab_active();
        for id in 0..6u64 {
            let plen = 8 + (id as usize % 3) * 4;
            let prompt: Vec<i32> =
                (0..plen).map(|j| ((id as i32) * 7 + j as i32) % 50 + 1).collect();
            e.submit(Request::new(
                id,
                prompt,
                SamplingParams { max_new_tokens: 5, ..Default::default() },
            ))
            .unwrap();
        }
        let mut done = e.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        let steps = e.metrics.counters.get("subvocab_steps").copied().unwrap_or(0);
        (done, active, steps)
    };
    let (base, base_active, base_steps) = run(false);
    assert!(!base_active && base_steps == 0);
    let (sub, sub_active, sub_steps) = run(subvocab_on());
    for (a, b) in base.iter().zip(&sub) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "request {} diverged", a.id);
    }
    if subvocab_on() && sub_active {
        assert!(sub_steps > 0, "active head never took the sub path");
    }
}
