//! Speculative-decode integration: the acceptance criteria of the
//! subsystem (DESIGN.md §9), runnable without artifacts.
//!
//! * **Greedy identity** — with tau → 0 for drafter and verifier, spec
//!   decode must be token-for-token identical to the baseline sequential
//!   decode path, for every drafter and every K.  The target model below
//!   is built so this is a theorem, not a flaky observation: its logits
//!   are a permutation of an evenly spaced grid, so the top-2 logit gap
//!   is exactly `3/V` at every context, and at tau = 1e-4 the scaled gap
//!   (`≈ 117`) towers over both the Gumbel noise spread (≲ 12) and the
//!   smallest representable accept uniform — no draw can ever flip an
//!   argmax, accept a wrong draft, or reject a right one.
//! * **Exactness under a hostile drafter** — an independent-model drafter
//!   whose proposals are almost always rejected must still produce the
//!   identical greedy output (the residual path reconstructs the target).

use flashsampling::sampling::philox::{self, Key};
use flashsampling::sampling::Transform;
use flashsampling::specdec::{
    baseline_generate, LogitModel, NGramDraft, RuntimeDraft, SpecDecodeLoop,
};

const V: usize = 256;
const TAU: f32 = 1e-4;

/// Deterministic target whose logits at every context are a permutation
/// of `{0, 3/V, 6/V, …}` — uniform gaps by construction (see module docs).
#[derive(Clone, Copy)]
struct GapModel {
    key: Key,
}

impl LogitModel for GapModel {
    fn vocab(&self) -> usize {
        V
    }

    fn logits(&self, ctx: &[i32]) -> Vec<f32> {
        let mut h: u32 = 0x9E37_79B9;
        for &t in ctx.iter().rev().take(4) {
            h = philox::philox4x32_10(
                [t as u32, h, 0, 0xA11],
                [self.key.lo, self.key.hi],
            )[0];
        }
        // v -> (h ^ v) & (V-1) is a bijection on 0..V (V is a power of
        // two), so the logits are a context-dependent permutation of the
        // evenly spaced grid.
        let mask = (V - 1) as u32;
        (0..V as u32)
            .map(|v| ((h ^ v) & mask) as f32 * (3.0 / V as f32))
            .collect()
    }
}

fn greedy_baseline(target: &GapModel, key: Key, prompt: &[i32], n: usize) -> Vec<i32> {
    baseline_generate(
        target,
        &Transform::with_temperature(TAU),
        key,
        prompt,
        n,
        0,
    )
}

#[test]
fn greedy_spec_decode_is_token_for_token_identical_to_baseline() {
    let target = GapModel { key: Key::new(1, 2) };
    let key = Key::new(7, 9);
    let prompt = vec![3, 1, 4, 1, 5, 9, 2, 6];
    let base = greedy_baseline(&target, key, &prompt, 48);
    assert_eq!(base.len(), 48);

    for k in [1usize, 2, 4, 8] {
        // Deterministic n-gram drafter (one-hot proposals).
        let mut ngram = NGramDraft { n: 3, vocab: V };
        let mut l = SpecDecodeLoop {
            target: &target,
            drafter: &mut ngram,
            transform: Transform::with_temperature(TAU),
            k,
            key,
        };
        let r = l.generate(&prompt, 48, 0);
        assert_eq!(r.tokens, base, "ngram drafter diverged at K={k}");

        // Same-model greedy drafter: q == p point masses ⇒ accept-all.
        let mut same = RuntimeDraft::new(target, TAU, Key::new(5, 5));
        let mut l = SpecDecodeLoop {
            target: &target,
            drafter: &mut same,
            transform: Transform::with_temperature(TAU),
            k,
            key,
        };
        let r = l.generate(&prompt, 48, 0);
        assert_eq!(r.tokens, base, "self drafter diverged at K={k}");
        assert!(
            (r.stats.acceptance_rate() - 1.0).abs() < 1e-12,
            "greedy self-drafting must accept everything: {:?}",
            r.stats
        );
        // Every full round emits K+1 tokens.
        assert!(
            (r.stats.tokens_per_step() - (48.0 / r.stats.rounds as f64)).abs()
                < 1e-9
        );
    }
}

#[test]
fn hostile_drafter_is_rejected_but_output_stays_exact() {
    // A drafter speaking a DIFFERENT language (independent permutation):
    // its greedy proposals match the target's argmax only by 1/V chance,
    // so nearly every round walks the rejection/residual path — and the
    // emitted tokens must still equal the baseline greedy output exactly.
    let target = GapModel { key: Key::new(1, 2) };
    let key = Key::new(7, 9);
    let prompt = vec![3, 1, 4, 1, 5, 9, 2, 6];
    let base = greedy_baseline(&target, key, &prompt, 40);

    let mut hostile = RuntimeDraft::new(GapModel { key: Key::new(8, 8) }, TAU, Key::new(6, 6));
    let mut l = SpecDecodeLoop {
        target: &target,
        drafter: &mut hostile,
        transform: Transform::with_temperature(TAU),
        k: 4,
        key,
    };
    let r = l.generate(&prompt, 40, 0);
    assert_eq!(r.tokens, base, "rejection path broke greedy identity");
    assert!(
        r.stats.acceptance_rate() < 0.3,
        "independent drafter accepted suspiciously often: {:?}",
        r.stats
    );
    // Mostly-rejected drafts ⇒ close to one token per round.
    assert!(r.stats.tokens_per_step() < 2.0, "{:?}", r.stats);
}

#[test]
fn spec_decode_replays_and_varies_with_the_session_key() {
    let target = GapModel { key: Key::new(3, 3) };
    let prompt = vec![1, 2, 1, 2, 1];
    let run = |key: Key| {
        let mut ngram = NGramDraft { n: 2, vocab: V };
        let mut l = SpecDecodeLoop {
            target: &target,
            drafter: &mut ngram,
            transform: Transform::default(), // tau = 1: genuinely stochastic
            k: 3,
            key,
        };
        l.generate(&prompt, 32, 0).tokens
    };
    assert_eq!(run(Key::new(1, 1)), run(Key::new(1, 1)));
    assert_ne!(run(Key::new(1, 1)), run(Key::new(2, 2)));
}
