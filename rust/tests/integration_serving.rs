//! End-to-end serving integration: the engine drives real AOT artifacts
//! (prefill → fused decode+sample → completion) through PJRT.
//!
//! Requires `make artifacts`; tests no-op (pass) with a note otherwise.

use flashsampling::coordinator::{
    Engine, EngineConfig, FinishReason, Priority, Request, SamplingParams,
};
use flashsampling::sampling::SamplerSpec;
use flashsampling::workload::WorkloadGen;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("NOTE: artifacts/ missing; run `make artifacts`");
        None
    }
}

fn engine(cfg: EngineConfig) -> Option<Engine> {
    artifacts_dir().map(|d| Engine::new(d, cfg).unwrap())
}

fn simple_request(id: u64, prompt: Vec<i32>, max_new: usize) -> Request {
    Request::new(
        id,
        prompt,
        SamplingParams { max_new_tokens: max_new, ..Default::default() },
    )
}

#[test]
fn single_request_completes() {
    let Some(mut e) = engine(EngineConfig::default()) else { return };
    e.submit(simple_request(1, vec![3, 14, 15, 9], 8)).unwrap();
    let done = e.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    let c = &done[0];
    assert_eq!(c.id, 1);
    assert_eq!(c.tokens.len(), 8);
    assert_eq!(c.finish, FinishReason::MaxTokens);
    let vocab = e.runtime().manifest().model.vocab as i32;
    assert!(c.tokens.iter().all(|&t| (0..vocab).contains(&t)));
    assert!(c.timing.ttft.is_some());
    assert_eq!(c.timing.token_latencies.len(), 7); // 8 tokens, 7 gaps
}

#[test]
fn batch_of_requests_all_complete() {
    let Some(mut e) = engine(EngineConfig::default()) else { return };
    for i in 0..6 {
        e.submit(simple_request(i, vec![1 + i as i32, 2, 3], 5 + i as usize))
            .unwrap();
    }
    let done = e.run_to_completion().unwrap();
    assert_eq!(done.len(), 6);
    for c in &done {
        assert_eq!(c.tokens.len(), 5 + c.id as usize);
    }
    assert_eq!(e.metrics.tokens_generated as usize, (5..=10).sum::<usize>());
}

#[test]
fn deterministic_across_engines_same_seed() {
    let Some(mut a) = engine(EngineConfig::default()) else { return };
    let Some(mut b) = engine(EngineConfig::default()) else { return };
    for e in [&mut a, &mut b] {
        e.submit(simple_request(1, vec![7, 8, 9], 6)).unwrap();
        e.submit(simple_request(2, vec![10, 11], 6)).unwrap();
    }
    let mut da = a.run_to_completion().unwrap();
    let mut db = b.run_to_completion().unwrap();
    da.sort_by_key(|c| c.id);
    db.sort_by_key(|c| c.id);
    for (x, y) in da.iter().zip(&db) {
        assert_eq!(x.tokens, y.tokens, "same seed must reproduce exactly");
    }
}

#[test]
fn different_seed_changes_samples() {
    let Some(mut a) = engine(EngineConfig::default()) else { return };
    let Some(mut b) = engine(EngineConfig { seed: 999, ..Default::default() })
    else {
        return;
    };
    for e in [&mut a, &mut b] {
        e.submit(simple_request(1, vec![7, 8, 9], 12)).unwrap();
    }
    let da = a.run_to_completion().unwrap();
    let db = b.run_to_completion().unwrap();
    assert_ne!(da[0].tokens, db[0].tokens);
}

#[test]
fn baseline_sampler_ab_switch_works() {
    // The §4.5 A/B: same engine semantics with the baseline decode artifact.
    let Some(mut e) = engine(EngineConfig {
        sampler: SamplerSpec::Multinomial,
        ..Default::default()
    }) else {
        return;
    };
    e.submit(simple_request(1, vec![5, 6], 6)).unwrap();
    let done = e.run_to_completion().unwrap();
    assert_eq!(done[0].tokens.len(), 6);
}

#[test]
fn stop_token_stops_generation() {
    let Some(mut e) = engine(EngineConfig::default()) else { return };
    e.submit(Request {
        id: 1,
        prompt: vec![4, 2],
        params: SamplingParams { max_new_tokens: 4, ..Default::default() },
        priority: Priority::default(),
    })
    .unwrap();
    let done = e.run_to_completion().unwrap();
    let first = done[0].tokens[0];
    // Re-run with the known first sample as a stop token: one token only.
    let Some(mut e2) = engine(EngineConfig::default()) else { return };
    e2.submit(Request {
        id: 1,
        prompt: vec![4, 2],
        params: SamplingParams {
            max_new_tokens: 4,
            ..SamplingParams::with_eos(first)
        },
        priority: Priority::default(),
    })
    .unwrap();
    let done2 = e2.run_to_completion().unwrap();
    assert_eq!(done2[0].tokens, vec![first]);
    assert_eq!(done2[0].finish, FinishReason::StopToken);
}

#[test]
fn spec_decode_engine_path_completes_deterministically() {
    // The speculative decode path (DESIGN.md §9) through the real fused
    // artifacts: exact budgets despite 1..=K+1 token bursts, burst sizes
    // within bounds, acceptance metrics recorded, and bitwise replay from
    // the session seed.
    let spec_cfg = || EngineConfig {
        sampler: SamplerSpec::SpecDecode { k: 4, ngram: 3 },
        ..Default::default()
    };
    let submit_all = |e: &mut Engine| {
        for i in 0..4u64 {
            // Repetitive prompts give the n-gram drafter matches.
            let p = 2 + i as i32;
            e.submit(Request {
                id: i,
                prompt: vec![p, 3, p, 3, p],
                params: SamplingParams { max_new_tokens: 9, ..Default::default() },
                priority: Priority::default(),
            })
            .unwrap();
        }
    };
    let Some(mut a) = engine(spec_cfg()) else { return };
    submit_all(&mut a);
    let mut da = a.run_to_completion().unwrap();
    da.sort_by_key(|c| c.id);
    assert_eq!(da.len(), 4);
    let vocab = a.runtime().manifest().model.vocab as i32;
    for c in &da {
        assert_eq!(c.tokens.len(), 9, "burst overshot the budget");
        assert!(c.tokens.iter().all(|&t| (0..vocab).contains(&t)));
    }
    assert!(a.metrics.counters.contains_key("spec_rounds"));
    assert!(!a.metrics.spec_tokens_per_step.is_empty());
    for &n in &a.metrics.spec_tokens_per_step {
        assert!((1..=5).contains(&n), "burst of {n} outside 1..=K+1");
    }
    // Replay: same seed, same artifacts => identical tokens.
    let Some(mut b) = engine(spec_cfg()) else { return };
    submit_all(&mut b);
    let mut db = b.run_to_completion().unwrap();
    db.sort_by_key(|c| c.id);
    for (x, y) in da.iter().zip(&db) {
        assert_eq!(x.tokens, y.tokens, "spec decode must replay exactly");
    }
}

#[test]
fn submit_validation() {
    let Some(mut e) = engine(EngineConfig::default()) else { return };
    assert!(e.submit(simple_request(1, vec![], 4)).is_err()); // empty
    assert!(e.submit(simple_request(2, vec![1; 100], 4)).is_err()); // > T bucket
    assert!(e.submit(simple_request(3, vec![99999], 4)).is_err()); // OOV
    assert!(e.submit(simple_request(4, vec![1; 64], 400)).is_err()); // > max_seq
}

#[test]
fn serve_open_loop_reports_metrics() {
    let Some(mut e) = engine(EngineConfig::default()) else { return };
    let vocab = e.runtime().manifest().model.vocab;
    let mut gen = WorkloadGen::new(42, 200.0, vocab);
    gen.prompt_len = flashsampling::workload::LengthDist::Uniform(4, 12);
    gen.output_len = flashsampling::workload::LengthDist::Uniform(3, 8);
    let reqs = gen.generate(12);
    let done = e.serve(reqs).unwrap();
    assert_eq!(done.len(), 12);
    assert_eq!(e.metrics.requests_completed, 12);
    assert!(e.metrics.median_tpot().is_some());
    assert!(e.metrics.median_ttft().is_some());
    assert!(e.metrics.throughput_tps() > 0.0);
    assert!(e.metrics.mean_batch() >= 1.0);
}

#[test]
fn mixed_temperatures_complete_in_one_engine() {
    let Some(mut e) = engine(EngineConfig::default()) else { return };
    for (id, tau) in [(1u64, 1.0f32), (2, 0.5)] {
        e.submit(Request {
            id,
            prompt: vec![id as i32, id as i32 + 1],
            params: SamplingParams {
                temperature: tau,
                max_new_tokens: 3,
                ..Default::default()
            },
            priority: Priority::default(),
        })
        .unwrap();
    }
    let done = e.run_to_completion().unwrap();
    assert_eq!(done.len(), 2);
    for c in &done {
        assert_eq!(c.tokens.len(), 3);
    }
}

#[test]
fn mixed_temperatures_fill_one_decode_bucket() {
    // The occupancy claim of the tau: [B] redesign: 8 requests at 4 distinct
    // temperatures decode as ONE full bucket per step — zero pad rows, mean
    // decode batch 8.  (The pre-redesign scheduler fragmented this into 4
    // two-row batches per decode round.)
    let Some(mut e) = engine(EngineConfig::default()) else { return };
    for i in 0..8u64 {
        e.submit(Request {
            id: i,
            prompt: vec![1 + i as i32; 4],
            params: SamplingParams {
                temperature: 0.25 * (1 + i % 4) as f32,
                max_new_tokens: 6,
                ..Default::default()
            },
            priority: Priority::default(),
        })
        .unwrap();
    }
    let done = e.run_to_completion().unwrap();
    assert_eq!(done.len(), 8);
    let pad = e.metrics.counters.get("decode_pad_rows").copied().unwrap_or(0);
    assert_eq!(pad, 0, "mixed-temperature decode left pad rows");
    assert_eq!(e.metrics.mean_batch(), 8.0, "decode buckets not full");
}

#[test]
fn prefill_applies_per_row_temperature() {
    // Regression for the first-token bug where `do_prefill` stretched
    // `seqs.first()`'s temperature over the whole batch: in a mixed-tau
    // prefill batch, each row's first token must be pathwise identical to
    // the same row of a batch that uniformly uses THAT row's temperature
    // (same seed, same Philox row/step => same noise; only tau differs).
    let prompts: [Vec<i32>; 2] = [vec![3, 14, 15], vec![9, 26, 53]];
    let run = |taus: [f32; 2]| -> Option<Vec<i32>> {
        let mut e = engine(EngineConfig::default())?;
        for (i, (prompt, tau)) in prompts.iter().zip(taus).enumerate() {
            e.submit(Request {
                id: i as u64,
                prompt: prompt.clone(),
                params: SamplingParams {
                    temperature: tau,
                    max_new_tokens: 1,
                    ..Default::default()
                },
                priority: Priority::default(),
            })
            .unwrap();
        }
        let mut done = e.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        Some(done.iter().map(|c| c.tokens[0]).collect())
    };
    let Some(mixed) = run([0.5, 2.0]) else { return };
    let uniform_lo = run([0.5, 0.5]).unwrap();
    let uniform_hi = run([2.0, 2.0]).unwrap();
    // Row 0 sampled at tau=0.5 in both the mixed and the uniform-0.5 run.
    assert_eq!(mixed[0], uniform_lo[0], "row 0 ignored its own temperature");
    // Row 1 sampled at tau=2.0 must match the uniform-2.0 run, NOT the
    // uniform-0.5 run it was glued to before the fix.
    assert_eq!(mixed[1], uniform_hi[1], "row 1 ignored its own temperature");
}

#[test]
fn unsupported_params_rejected_at_submit() {
    // The fused artifacts carry per-row tau only (ABI v2); richer params
    // must fail loudly at submit instead of silently sampling wrong.
    let Some(mut e) = engine(EngineConfig::default()) else { return };
    let err = e
        .submit(Request {
            id: 1,
            prompt: vec![1, 2],
            params: SamplingParams { top_k: Some(8), ..Default::default() },
            priority: Priority::default(),
        })
        .unwrap_err();
    assert!(err.to_string().contains("top_k"), "{err}");
    // Stop tokens and temperature ARE supported.
    e.submit(Request {
        id: 2,
        prompt: vec![1, 2],
        params: SamplingParams {
            temperature: 0.3,
            stop_tokens: vec![0],
            ..Default::default()
        },
        priority: Priority::default(),
    })
    .unwrap();
}

#[test]
fn kv_exhaustion_preempts_without_corruption() {
    // A pool of 3 blocks x 16 tokens can hold ~1 sequence; submitting 3
    // forces the scheduler through the preemption/serialization path.
    let Some(mut e) = engine(EngineConfig {
        kv_blocks: 3,
        kv_block_size: 16,
        ..Default::default()
    }) else {
        return;
    };
    for i in 0..3 {
        e.submit(simple_request(i, vec![2 + i as i32; 6], 6)).unwrap();
    }
    let done = e.run_to_completion().unwrap();
    // Everyone eventually completes (or is cleanly rejected), nothing hangs.
    assert_eq!(done.len(), 3);
    for c in &done {
        assert!(
            c.finish == FinishReason::MaxTokens
                || c.finish == FinishReason::Rejected,
            "{:?}",
            c.finish
        );
    }
}

#[test]
fn batch_composition_change_preserves_kv_state() {
    // Regression for the device-resident KV cache (§Perf L3): when one
    // sequence finishes mid-batch, the survivors' KV must be synced from
    // the cached literals before the next (smaller) batch is gathered.
    let Some(mut e) = engine(EngineConfig::default()) else { return };
    // Two sequences with different budgets: #1 finishes first.
    e.submit(simple_request(1, vec![5, 6, 7], 2)).unwrap();
    e.submit(simple_request(2, vec![8, 9], 8)).unwrap();
    let done = e.run_to_completion().unwrap();
    assert_eq!(done.len(), 2);

    // The long request's tokens must match a run where it was alone with
    // the same engine seed *after* the short one left... that exact replay
    // isn't expected (batch slots differ); instead assert determinism of
    // the mixed run itself:
    let Some(mut e2) = engine(EngineConfig::default()) else { return };
    e2.submit(simple_request(1, vec![5, 6, 7], 2)).unwrap();
    e2.submit(simple_request(2, vec![8, 9], 8)).unwrap();
    let mut d1 = done;
    let mut d2 = e2.run_to_completion().unwrap();
    d1.sort_by_key(|c| c.id);
    d2.sort_by_key(|c| c.id);
    for (a, b) in d1.iter().zip(&d2) {
        assert_eq!(a.tokens, b.tokens);
    }
}

#[test]
fn decode_cache_fast_path_engages() {
    let Some(mut e) = engine(EngineConfig::default()) else { return };
    for i in 0..4 {
        e.submit(simple_request(i, vec![1 + i as i32; 4], 12)).unwrap();
    }
    e.run_to_completion().unwrap();
    // Steady-state steps after the first decode reuse the cached KV.
    let hits = e.metrics.counters.get("decode_cache_hits").copied().unwrap_or(0);
    assert!(hits >= 8, "cache hits = {hits}");
}
