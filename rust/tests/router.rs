//! Multi-replica router integration suite (DESIGN.md §13).
//!
//! CPU-only and always running: property tests over `Router<SimReplica>`
//! — real KV manager + radix cache + stream event queues per replica,
//! deterministic sim tokens — covering replay-stable dispatch, the
//! randomized abort/drain leak bound, and the prefix-affinity win over
//! least-loaded on session traffic (two ISSUE acceptance criteria).
//! Engine-backed suites at the bottom are artifact-gated like the other
//! integration tests.
//!
//! CI matrix contract: `FS_TEST_REPLICAS` pins the replica count the
//! property tests run at (default 2), `FS_TEST_PREFIX_CACHING` (`0`
//! disables) builds every replica with the prefix cache off — crossing
//! them checks that routing correctness never depends on cache state.

use std::collections::BTreeMap;

use flashsampling::coordinator::{
    Engine, EngineConfig, EngineError, Request, RequestHandle, SamplingParams,
};
use flashsampling::router::{
    sim_router, DispatchPolicy, EngineBackend, Router, SimReplica,
    SimReplicaConfig,
};
use flashsampling::testutil;

/// CI matrix override: replica count for the property tests.
fn test_replicas() -> usize {
    std::env::var("FS_TEST_REPLICAS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(2)
}

/// CI matrix override: prefix caching on unless `FS_TEST_PREFIX_CACHING=0`.
fn prefix_caching_on() -> bool {
    std::env::var("FS_TEST_PREFIX_CACHING").map_or(true, |v| v != "0")
}

fn sim_cfg() -> SimReplicaConfig {
    SimReplicaConfig { prefix_caching: prefix_caching_on(), ..Default::default() }
}

fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> Request {
    Request::new(
        id,
        prompt,
        SamplingParams { max_new_tokens: max_new, ..Default::default() },
    )
}

/// Multi-turn session prompts: `sessions` conversations over
/// `num_sys` shared 32-token system prompts, one growing 16-token turn
/// per wave (same integer recipe as `repro router-identity` and the
/// bench mirror).
fn session_prompt(session: u64, turns_done: u64, num_sys: u64) -> Vec<i32> {
    let sys = session % num_sys;
    let mut p: Vec<i32> =
        (0..32).map(|j| ((sys * 97 + j * 13 + 5) % 2048) as i32).collect();
    for t in 0..=turns_done {
        p.extend(
            (0..16u64).map(|j| ((session * 59 + t * 31 + j * 7 + 11) % 2048) as i32),
        );
    }
    p
}

/// Drain a router to quiescence, collecting completions (id -> tokens)
/// in completion order.
fn drain(r: &mut Router<SimReplica>) -> Vec<(u64, Vec<i32>)> {
    let mut done = Vec::new();
    let mut idle = 0;
    while r.pending() > 0 {
        let step = r.step().expect("sim step");
        if step.is_empty() {
            idle += 1;
            if idle > 8 {
                if let Some(c) = r.reject_unschedulable() {
                    done.push((c.id, c.tokens));
                    idle = 0;
                    continue;
                }
            }
            assert!(idle < 64, "sim livelock");
        } else {
            idle = 0;
        }
        for c in step {
            done.push((c.id, c.tokens));
        }
    }
    done
}

// ---------------------------------------------------------------------
// CPU-only property tests.
// ---------------------------------------------------------------------

#[test]
fn prop_dispatch_is_deterministic_and_replay_stable() {
    // Same submissions => same placements and same streams, at the CI
    // matrix replica count, for every policy, over randomized workloads.
    let n = test_replicas();
    for policy in [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::LeastLoaded,
        DispatchPolicy::PrefixAffinity,
    ] {
        testutil::cases(12, 0xD15B, |g| {
            let sessions = g.usize_in(2, 6) as u64;
            let turns = g.usize_in(1, 3) as u64;
            let run = || {
                let mut r = sim_router(n, policy, sim_cfg());
                let mut owners = BTreeMap::new();
                let mut done = Vec::new();
                for turn in 0..turns {
                    for s in 0..sessions {
                        let id = turn * sessions + s;
                        r.submit(req(id, session_prompt(s, turn, 2), 3)).unwrap();
                        owners.insert(id, r.owner_of(id).unwrap());
                    }
                    done.extend(drain(&mut r));
                }
                (owners, done)
            };
            let (o1, d1) = run();
            let (o2, d2) = run();
            assert_eq!(o1, o2, "{policy} placements not replay-stable");
            assert_eq!(d1, d2, "{policy} streams not replay-stable");
            assert!(o1.values().all(|&o| o < n));
        });
    }
}

#[test]
fn prop_any_abort_schedule_leaves_every_replica_balanced() {
    // ISSUE acceptance criterion: randomized abort schedules leak zero
    // KV blocks and zero prefix refs on EVERY replica, and every
    // handle's event queue drains to a terminal event at quiescence.
    let n = test_replicas();
    testutil::cases(24, 0xAB0B, |g| {
        let mut r = sim_router(n, DispatchPolicy::PrefixAffinity, sim_cfg());
        let sessions = g.usize_in(3, 8) as u64;
        let mut handles: Vec<RequestHandle> = Vec::new();
        for turn in 0..3u64 {
            let mut live = Vec::new();
            for s in 0..sessions {
                let id = turn * sessions + s;
                handles
                    .push(r.submit(req(id, session_prompt(s, turn, 3), 4)).unwrap());
                live.push(id);
            }
            // Abort a random subset while prefill/decode are in flight.
            for _ in 0..g.usize_in(0, 3) {
                let id = *g.choose(&live);
                if r.owner_of(id).is_some() {
                    let c = r.abort(id).unwrap();
                    assert_eq!(c.id, id);
                }
            }
            drain(&mut r);
        }
        assert_eq!(r.pending(), 0);
        // Per-replica balance, not just the sum.
        for (i, e) in r.replicas().iter().enumerate() {
            assert_eq!(e.kv_unaccounted_blocks(), 0, "replica {i} leaked blocks");
            assert_eq!(e.prefix_attached_refs(), 0, "replica {i} dangling refs");
        }
        for h in &handles {
            let evs = h.drain();
            assert!(h.is_finished(), "request {} never finished", h.id());
            assert!(
                evs.last().is_some_and(|e| e.finish.is_some()),
                "request {} queue lacks a terminal event",
                h.id()
            );
            assert!(h.try_next().is_none(), "queue not drained");
        }
    });
}

#[test]
fn prefix_affinity_beats_least_loaded_on_session_traffic() {
    // ISSUE acceptance criterion: strictly higher aggregate hit rate at
    // 2+ replicas, with no replica starved.  Needs the prefix cache;
    // the FS_TEST_PREFIX_CACHING=0 matrix leg exercises the suites
    // above instead.
    if !prefix_caching_on() {
        eprintln!("NOTE: FS_TEST_PREFIX_CACHING=0; skipping hit-rate bound");
        return;
    }
    // 12 sessions over 6 shared system prompts, waves submitted in
    // rotated order (turn + k) % 12: with a fixed order and drained
    // waves, least-loaded's deterministic tiebreaks pin each session to
    // one replica (accidental perfect affinity) and the policies tie.
    for n in [2usize, test_replicas().max(2)] {
        let run = |policy| {
            let mut r = sim_router(n, policy, sim_cfg());
            for turn in 0..3u64 {
                for k in 0..12u64 {
                    let s = (turn + k) % 12;
                    let id = turn * 12 + s;
                    r.submit(req(id, session_prompt(s, turn, 6), 3)).unwrap();
                }
                drain(&mut r);
            }
            let completed: Vec<u64> = r
                .replicas()
                .iter()
                .map(|e| e.metrics.requests_completed)
                .collect();
            (r.prefix_hit_rate().expect("prefill ran"), completed)
        };
        let (aff, aff_done) = run(DispatchPolicy::PrefixAffinity);
        let (ll, _) = run(DispatchPolicy::LeastLoaded);
        assert!(
            aff > ll,
            "affinity {aff:.4} must strictly beat least-loaded {ll:.4} at {n} replicas"
        );
        assert!(
            aff_done.iter().all(|&c| c > 0),
            "a replica starved under affinity: {aff_done:?}"
        );
    }
}

#[test]
fn one_replica_router_is_the_bare_replica() {
    // Identity at the sim level: same completion order, clock, and
    // accounting as a directly-driven replica (the Engine-backed
    // byte-identity version is artifact-gated below).
    let submit_all = |target: &mut dyn FnMut(Request)| {
        for turn in 0..3u64 {
            for s in 0..5u64 {
                target(req(turn * 5 + s, session_prompt(s, turn, 2), 3));
            }
        }
    };
    let mut bare = SimReplica::new(sim_cfg());
    let mut bare_done = Vec::new();
    submit_all(&mut |rq| {
        bare.submit(rq).unwrap();
    });
    let mut idle = 0;
    while bare.pending() > 0 {
        let step = bare.step().unwrap();
        if step.is_empty() {
            idle += 1;
            assert!(idle < 64);
        } else {
            idle = 0;
        }
        bare_done.extend(step.into_iter().map(|c| (c.id, c.tokens)));
    }
    for policy in [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::LeastLoaded,
        DispatchPolicy::PrefixAffinity,
    ] {
        let mut r = sim_router(1, policy, sim_cfg());
        let mut routed = Vec::new();
        submit_all(&mut |rq| {
            r.submit(rq).unwrap();
        });
        let mut idle = 0;
        while r.pending() > 0 {
            let step = r.step().unwrap();
            if step.is_empty() {
                idle += 1;
                assert!(idle < 64);
            } else {
                idle = 0;
            }
            routed.extend(step.into_iter().map(|c| (c.id, c.tokens)));
        }
        assert_eq!(routed, bare_done, "{policy} at 1 replica diverged");
        assert_eq!(r.clock(), bare.clock());
        assert_eq!(
            r.replicas()[0].metrics.cached_prefill_tokens,
            bare.metrics.cached_prefill_tokens
        );
    }
}

#[test]
fn router_level_duplicate_and_unknown_ids_are_typed_errors() {
    let mut r = sim_router(test_replicas().max(2), DispatchPolicy::RoundRobin, sim_cfg());
    r.submit(req(7, session_prompt(0, 0, 1), 8)).unwrap();
    // Round-robin would hand id 7 to a DIFFERENT replica — the router
    // must still refuse it (ownership is global).
    assert!(matches!(
        r.submit(req(7, session_prompt(1, 0, 1), 8)),
        Err(EngineError::DuplicateRequestId { id: 7 })
    ));
    assert!(matches!(
        r.abort(99),
        Err(EngineError::UnknownRequest { id: 99 })
    ));
    let c = r.abort(7).unwrap();
    assert_eq!(c.id, 7);
    assert_eq!(r.pending(), 0);
}

// ---------------------------------------------------------------------
// Artifact-gated Engine-backed suites.
// ---------------------------------------------------------------------

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("NOTE: artifacts/ missing; run `make artifacts`");
        None
    }
}

fn engine_cfg() -> EngineConfig {
    EngineConfig {
        seed: 0x70C7E5,
        prefix_caching: prefix_caching_on(),
        ..Default::default()
    }
}

/// Short in-vocab prompts that fit the smallest prefill bucket.
fn engine_requests() -> Vec<Request> {
    (0..6u64)
        .map(|i| {
            let prompt: Vec<i32> =
                (0..12).map(|j| ((i * 37 + j * 11 + 3) % 2048) as i32).collect();
            req(i, prompt, 4)
        })
        .collect()
}

#[test]
fn engine_one_replica_router_token_identity() {
    // The tentpole acceptance criterion at the Engine level: a 1-replica
    // router produces byte-identical tokens (same Philox coordinates) to
    // the bare engine on the same closed-loop script.
    let Some(dir) = artifacts_dir() else { return };
    let mut bare = Engine::new(&dir, engine_cfg()).unwrap();
    let mut expect = BTreeMap::new();
    for rq in engine_requests() {
        bare.submit(rq).unwrap();
    }
    while bare.pending() > 0 {
        for c in bare.step().unwrap() {
            expect.insert(c.id, c.tokens);
        }
    }
    for policy in [DispatchPolicy::RoundRobin, DispatchPolicy::PrefixAffinity] {
        let e = Engine::new(&dir, engine_cfg()).unwrap();
        let mut r = Router::new(vec![e], policy).unwrap();
        let mut got = BTreeMap::new();
        for rq in engine_requests() {
            r.submit(rq).unwrap();
        }
        while r.pending() > 0 {
            for c in r.step().unwrap() {
                got.insert(c.id, c.tokens);
            }
        }
        assert_eq!(got, expect, "{policy}: 1-replica router != bare engine");
    }
}

#[test]
fn engine_multi_replica_dispatch_is_replay_stable_and_drains() {
    // Two real engines behind the router: rerunning the same submission
    // sequence reproduces every placement and every token stream
    // bit-for-bit (the N-replica acceptance criterion — placement
    // changes batch composition and step counters, so the bound is
    // replay stability, not equality with the single-engine run), every
    // handle drains to a terminal event, and both replicas balance
    // their pools.
    let Some(dir) = artifacts_dir() else { return };
    let run = || {
        let engines: Vec<Engine> =
            (0..2).map(|_| Engine::new(&dir, engine_cfg()).unwrap()).collect();
        let mut r = Router::new(engines, DispatchPolicy::PrefixAffinity).unwrap();
        let mut handles = Vec::new();
        let mut owners = BTreeMap::new();
        for rq in engine_requests() {
            let id = rq.id;
            handles.push(r.submit(rq).unwrap());
            owners.insert(id, r.owner_of(id).unwrap());
        }
        let mut got = BTreeMap::new();
        while r.pending() > 0 {
            for c in r.step().unwrap() {
                got.insert(c.id, c.tokens);
            }
        }
        for h in &handles {
            assert!(h.is_finished());
            assert!(h.drain().last().is_some_and(|e| e.finish.is_some()));
        }
        for (i, e) in r.replicas().iter().enumerate() {
            assert_eq!(
                EngineBackend::kv_unaccounted_blocks(e),
                0,
                "replica {i} leaked"
            );
            assert_eq!(
                EngineBackend::prefix_attached_refs(e),
                0,
                "replica {i} refs"
            );
        }
        (owners, got)
    };
    let (o1, t1) = run();
    let (o2, t2) = run();
    assert_eq!(o1, o2, "placements not replay-stable");
    assert_eq!(t1, t2, "token streams not replay-stable");
}
