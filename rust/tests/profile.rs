//! Edge-case property tests for the modeled-time profiler
//! (DESIGN.md §15): randomized abort / reject / preempt schedules
//! through the engine-mirroring scheduler sim must always satisfy the
//! conservation laws, and chunk-interleaved batches must never
//! double-count a window.

use flashsampling::profile::{
    profile_trace, Phase, PriceTable, StepClockPricer,
};
use flashsampling::testutil::schedsim::{Sim, SimConfig, SimRequest};
use flashsampling::trace::TraceLevel;

/// Deterministic xorshift64* — the schedules are random-looking but
/// replay identically, so a failure is reproducible from the seed.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

fn random_schedule(rng: &mut Rng) -> (SimConfig, Vec<SimRequest>) {
    let mut cfg = SimConfig::small(256);
    cfg.trace_level = TraceLevel::Full;
    let chunked = rng.below(2) == 0;
    if chunked {
        cfg.sched.prefill_chunk_tokens = 16;
    }
    if rng.below(2) == 0 {
        cfg.swap_blocks = 64;
    }
    cfg.spec_k = [0, 0, 2, 3][rng.below(4) as usize];
    if rng.below(3) == 0 {
        cfg.sched.aging_steps = 4;
    }
    let n = 3 + rng.below(4);
    let reqs: Vec<SimRequest> = (0..n)
        .map(|id| {
            // With chunking off, prompts past the largest prefill
            // bucket (64) are rejected at submit — inject some.
            let prompt_len = if !chunked && rng.below(4) == 0 {
                80 + rng.below(40) as usize
            } else {
                8 + rng.below(52) as usize
            };
            SimRequest {
                id,
                prompt_len,
                max_new_tokens: 1 + rng.below(8) as usize,
                arrival_step: 0,
            }
        })
        .collect();
    for id in 0..n {
        if rng.below(3) == 0 {
            cfg.force_abort.push((1 + rng.below(10), id));
        }
    }
    if cfg.swap_blocks > 0 {
        for id in 0..n {
            if rng.below(3) == 0 {
                cfg.force_preempt.push((1 + rng.below(10), id));
            }
        }
    }
    (cfg, reqs)
}

/// Randomized schedules: conservation under both pricers, terminal
/// classification (aborted → closed partial span, rejected → zero
/// compute), and stamp agreement with the sim's own outcome
/// certificates.  Aggregated coverage asserts prove the randomness
/// actually exercised every edge, not just the happy path.
#[test]
fn randomized_schedules_conserve_and_classify() {
    let mut rng = Rng(0x9E37_79B9_7F4A_7C15);
    let (mut aborts, mut rejects, mut chunks, mut swaps, mut specs) =
        (0u64, 0u64, 0u64, 0u64, 0u64);
    // Round 0 is a deterministic swap-heavy script (the randomized
    // rounds may or may not land their forced preempts on a
    // preemptible step); rounds 1.. are random.
    for round in 0..25 {
        let (cfg, reqs) = if round == 0 {
            let mut cfg = SimConfig::small(256);
            cfg.trace_level = TraceLevel::Full;
            cfg.swap_blocks = 64;
            cfg.force_preempt = vec![(3, 0), (5, 1)];
            cfg.force_abort = vec![(7, 2)];
            let reqs = (0..3)
                .map(|id| SimRequest {
                    id,
                    prompt_len: 20,
                    max_new_tokens: 12,
                    arrival_step: 0,
                })
                .collect();
            (cfg, reqs)
        } else {
            random_schedule(&mut rng)
        };
        let mut sim = Sim::new(cfg);
        sim.drive(&reqs);
        let step = profile_trace(0, &sim.trace, &StepClockPricer)
            .unwrap_or_else(|e| panic!("round {round}: {e:#}"));
        step.check()
            .unwrap_or_else(|e| panic!("round {round}: {e:#}"));
        let modeled = profile_trace(0, &sim.trace, &PriceTable::canonical())
            .unwrap();
        modeled.check().unwrap();
        assert_eq!(step.requests.len(), sim.outcomes.len(), "round {round}");
        for r in &step.requests {
            let o = &sim.outcomes[&r.id];
            assert_eq!(
                r.ttft_us, o.ttft_weighted,
                "round {round} request {}",
                r.id
            );
            assert_eq!(
                r.token_times_us, o.token_times,
                "round {round} request {}",
                r.id
            );
            match r.finish.as_str() {
                "aborted" => {
                    // Aborts close the span: a terminal stamp exists
                    // and the partial phases still balance (check()
                    // above proved phases + queue == span).
                    assert!(r.finish_us.is_some(), "round {round}");
                    aborts += 1;
                }
                "rejected" => {
                    // Rejects never compute or emit.
                    assert_eq!(r.tokens, 0, "round {round}");
                    assert_eq!(r.ttft_us, None, "round {round}");
                    rejects += 1;
                }
                _ => {}
            }
        }
        for w in &step.windows {
            match w.phase {
                Phase::Chunk => chunks += 1,
                Phase::Swap => swaps += 1,
                Phase::Spec => specs += 1,
                _ => {}
            }
        }
    }
    assert!(aborts > 0, "no abort exercised");
    assert!(rejects > 0, "no rejection exercised");
    assert!(chunks > 0, "no chunk window exercised");
    assert!(swaps > 0, "no swap window exercised");
    assert!(specs > 0, "no spec burst exercised");
}

/// Chunk windows interleave with other requests' decode steps; each
/// window must be charged exactly once, to exactly its own request.
#[test]
fn chunk_interleave_does_not_double_count() {
    let mut cfg = SimConfig::small(256);
    cfg.trace_level = TraceLevel::Full;
    cfg.sched.prefill_chunk_tokens = 16;
    // A short request decodes while the long prompt chunks through.
    let reqs = vec![
        SimRequest { id: 0, prompt_len: 12, max_new_tokens: 8, arrival_step: 0 },
        SimRequest { id: 1, prompt_len: 60, max_new_tokens: 2, arrival_step: 0 },
    ];
    let mut sim = Sim::new(cfg);
    sim.drive(&reqs);
    let p = profile_trace(0, &sim.trace, &StepClockPricer).unwrap();
    p.check().unwrap();
    // Every chunk window belongs to exactly one request, so the sum of
    // per-request chunk time equals the sum of chunk window durations —
    // an interleaved double-count would break this equality.
    let window_chunk: u64 = p
        .windows
        .iter()
        .filter(|w| w.phase == Phase::Chunk)
        .map(|w| {
            assert_eq!(w.participants.len(), 1, "chunk window shared");
            w.dur_us
        })
        .sum();
    let request_chunk: u64 = p.requests.iter().map(|r| r.chunk_us).sum();
    assert!(window_chunk > 0, "no chunk windows in the interleave run");
    assert_eq!(window_chunk, request_chunk);
    // The decoding request accrues no chunk time.
    let short = p.requests.iter().find(|r| r.id == 0).unwrap();
    assert_eq!(short.chunk_us, 0);
    assert!(short.decode_us > 0);
}

/// A mixed schedule run twice profiles to the same digest under the
/// modeled pricer (replay determinism end-to-end through the sim).
#[test]
fn modeled_profile_replays_bit_identically() {
    let mut cfg = SimConfig::small(256);
    cfg.trace_level = TraceLevel::Full;
    cfg.sched.prefill_chunk_tokens = 16;
    cfg.swap_blocks = 64;
    cfg.spec_k = 2;
    cfg.force_abort = vec![(4, 1)];
    cfg.force_preempt = vec![(6, 0)];
    let reqs: Vec<SimRequest> = (0..4)
        .map(|id| SimRequest {
            id,
            prompt_len: 40 + (id as usize % 2) * 20,
            max_new_tokens: 5,
            arrival_step: 0,
        })
        .collect();
    let digest = |cfg: &SimConfig| {
        let mut sim = Sim::new(cfg.clone());
        sim.drive(&reqs);
        let p = flashsampling::profile::profile_tracks(
            &[(0, &sim.trace)],
            &PriceTable::canonical(),
        )
        .unwrap();
        p.check().unwrap();
        p.digest()
    };
    assert_eq!(digest(&cfg), digest(&cfg));
}
