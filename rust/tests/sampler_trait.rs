//! The `ExactSampler` trait boundary: registry construction, Philox
//! stream-key determinism, and pathwise identity with the per-algorithm
//! module functions.
//!
//! The load-bearing claim mirrors the kernel one: selecting a sampler by
//! config string must not change a single drawn token — the trait adapter
//! consumes exactly the Philox streams its module functions do, so results
//! are reproducible from `(spec, seed, row, step)` alone.

#[allow(unused_imports)]
use flashsampling::sampling::ExactSampler;
use flashsampling::coordinator::SamplingParams;
use flashsampling::sampling::{
    self, build_sampler, distributed, grouped, gumbel, multinomial, online,
    philox, topk, Key, RowCtx, SamplerSpec, Transform, SAMPLER_NAMES,
};

fn toy_logits(n: usize, seed: u64) -> Vec<f32> {
    let key = Key::from_seed(seed ^ 0x7EA7);
    (0..n)
        .map(|i| 3.0 * (philox::uniform_at(key, i as u32, 0, 3, 0) - 0.5))
        .collect()
}

/// The grid of specs exercised across the boundary (all six names, with
/// non-default parameters where they exist).
const SPECS: [&str; 8] = [
    "gumbel",
    "gumbel:tile=96",
    "multinomial",
    "grouped:group=48",
    "online:group=48",
    "distributed:ranks=4",
    "topk:k=8,tile=96",
    "topk:k=4,p=0.9",
];

#[test]
fn registry_covers_all_six_samplers() {
    assert_eq!(SAMPLER_NAMES.len(), 6);
    for name in SAMPLER_NAMES {
        assert_eq!(build_sampler(name).unwrap().name(), name);
    }
    let built: Vec<String> = sampling::default_samplers()
        .iter()
        .map(|s| s.name().to_string())
        .collect();
    assert_eq!(built, SAMPLER_NAMES.to_vec());
}

/// Every spec string round-trips `parse -> Display -> parse` onto the same
/// typed value, and both parses build samplers that draw identically.
#[test]
fn spec_roundtrip_parse_display_parse() {
    let logits = toy_logits(200, 7);
    let t = Transform::default();
    for spec_str in SPECS {
        let spec: SamplerSpec = spec_str.parse().unwrap();
        let rendered = spec.to_string();
        let reparsed: SamplerSpec = rendered.parse().unwrap();
        assert_eq!(spec, reparsed, "'{spec_str}' -> '{rendered}'");
        let a = spec.build().unwrap();
        let b = reparsed.build().unwrap();
        for step in 0..10 {
            let ctx = RowCtx { transform: &t, key: Key::new(1, 2), row: 0, step };
            assert_eq!(a.sample_row(&logits, ctx), b.sample_row(&logits, ctx));
        }
    }
}

/// The `build_sampler` string shim constructs samplers identical to the
/// typed path — legacy config strings keep working bit-for-bit.
#[test]
fn legacy_strings_build_identical_samplers() {
    let logits = toy_logits(300, 8);
    let t = Transform::default();
    let pairs: [(&str, SamplerSpec); 4] = [
        ("grouped:group=64", SamplerSpec::Grouped { group: 64 }),
        ("gumbel:tile=96", SamplerSpec::Gumbel { tile: Some(96) }),
        ("distributed:ranks=4", SamplerSpec::Distributed { ranks: 4 }),
        (
            "topk:k=8,p=0.9,tile=96",
            SamplerSpec::TopK { k: 8, top_p: 0.9, tile: 96 },
        ),
    ];
    for (legacy, typed) in pairs {
        assert_eq!(legacy.parse::<SamplerSpec>().unwrap(), typed);
        let via_string = build_sampler(legacy).unwrap();
        let via_typed = typed.build().unwrap();
        assert_eq!(via_string.name(), via_typed.name());
        for step in 0..20 {
            let ctx = RowCtx { transform: &t, key: Key::new(4, 2), row: 1, step };
            assert_eq!(
                via_string.sample_row(&logits, ctx),
                via_typed.sample_row(&logits, ctx),
                "{legacy} step {step}"
            );
        }
    }
}

/// Heterogeneous batches through `sample_batch_rows`: each row keeps the
/// exact draw it would make alone under its own transform — batching rows
/// with different parameters changes nothing (the scheduler-coalescing
/// exactness contract).
#[test]
fn heterogeneous_batch_rows_sample_independently() {
    let vocab = 128usize;
    let logits = toy_logits(4 * vocab, 9);
    let key = Key::new(31, 7);
    // Row 1 carries a per-request seed: its RowCtx key comes from
    // SamplingParams::row_key, not the session key.
    let seeded = SamplingParams { seed: Some(0xFEED), ..Default::default() };
    let row_keys =
        [key, seeded.row_key(key), key, key];
    assert_ne!(row_keys[1], key);
    // Four rows: two temperatures, one top-k truncation, one bias mask.
    let masked: Vec<f32> = {
        let mut bias = vec![f32::NEG_INFINITY; vocab];
        for b in bias[32..64].iter_mut() {
            *b = 0.0;
        }
        bias
    };
    let transforms: Vec<Transform> = vec![
        Transform::with_temperature(0.5),
        Transform::with_temperature(2.0),
        Transform::default().truncated(&logits[2 * vocab..3 * vocab], Some(8), None),
        Transform { temperature: 1.0, bias: Some(masked) },
    ];
    for spec in SPECS {
        let s = build_sampler(spec).unwrap();
        for step in 0..15 {
            let ctxs: Vec<RowCtx<'_>> = transforms
                .iter()
                .enumerate()
                .map(|(b, t)| RowCtx {
                    transform: t,
                    key: row_keys[b],
                    row: b as u32,
                    step,
                })
                .collect();
            let batched = s.sample_batch_rows(&logits, vocab, &ctxs);
            for (b, row) in logits.chunks_exact(vocab).enumerate() {
                let solo = s.sample_row(row, ctxs[b]);
                assert_eq!(batched[b], solo, "{spec} row {b} step {step}");
            }
            // Row 3's mask must hold through the batched path too.
            let d = batched[3].unwrap();
            assert!(
                (32..64).contains(&(d.index as usize)),
                "{spec}: masked row escaped its support"
            );
        }
    }
}

/// Same spec + same Philox coordinates => identical draw, across separately
/// constructed boxed instances (no hidden per-instance state).
#[test]
fn stream_key_determinism_across_trait_boundary() {
    let logits = toy_logits(300, 1);
    let t = Transform::default();
    for spec in SPECS {
        let a = build_sampler(spec).unwrap();
        let b = build_sampler(spec).unwrap();
        for step in 0..30 {
            let ctx = RowCtx { transform: &t, key: Key::new(5, 6), row: 2, step };
            assert_eq!(
                a.sample_row(&logits, ctx),
                b.sample_row(&logits, ctx),
                "{spec} step {step}"
            );
        }
    }
}

/// Different seeds (stream keys) must decorrelate draws: over many steps at
/// least one sampled index differs for every sampler.
#[test]
fn distinct_keys_give_distinct_streams() {
    let logits = toy_logits(256, 2);
    let t = Transform::default();
    for spec in SPECS {
        let s = build_sampler(spec).unwrap();
        let mut any_differ = false;
        for step in 0..50 {
            let d1 = s
                .sample_row(
                    &logits,
                    RowCtx { transform: &t, key: Key::new(1, 0), row: 0, step },
                )
                .unwrap();
            let d2 = s
                .sample_row(
                    &logits,
                    RowCtx { transform: &t, key: Key::new(2, 0), row: 0, step },
                )
                .unwrap();
            if d1.index != d2.index {
                any_differ = true;
                break;
            }
        }
        assert!(any_differ, "{spec}: keys 1 and 2 drew identical streams");
    }
}

/// The boxed trait objects are pathwise identical to direct module-function
/// calls — the registry adds selection, never different randomness.
#[test]
fn trait_objects_match_module_functions() {
    let logits = toy_logits(500, 3);
    let t = Transform::default();
    let key = Key::new(77, 88);
    for step in 0..20 {
        let ctx = RowCtx { transform: &t, key, row: 1, step };

        let d = build_sampler("gumbel").unwrap().sample_row(&logits, ctx).unwrap();
        let g = gumbel::sample_row(&logits, &t, key, 1, step).unwrap();
        assert_eq!(d.index, g.index);

        let d = build_sampler("gumbel:tile=96")
            .unwrap()
            .sample_row(&logits, ctx)
            .unwrap();
        let g = gumbel::sample_row_tiled(&logits, &t, key, 1, step, 96).unwrap();
        assert_eq!(d.index, g.index);

        let d = build_sampler("multinomial")
            .unwrap()
            .sample_row(&logits, ctx)
            .unwrap();
        let m = multinomial::sample_row(&logits, &t, key, 1, step).unwrap();
        assert_eq!(d.index, m);

        let d = build_sampler("grouped:group=48")
            .unwrap()
            .sample_row(&logits, ctx)
            .unwrap();
        let (idx, lz) = grouped::sample_row(&logits, 48, &t, key, 1, step).unwrap();
        assert_eq!((d.index, d.log_z), (idx, Some(lz)));

        let d = build_sampler("online:group=48")
            .unwrap()
            .sample_row(&logits, ctx)
            .unwrap();
        let (idx, lz) = online::sample_row(&logits, 48, &t, key, 1, step).unwrap();
        assert_eq!((d.index, d.log_z), (idx, Some(lz)));

        let d = build_sampler("distributed:ranks=4")
            .unwrap()
            .sample_row(&logits, ctx)
            .unwrap();
        let vs = logits.len() / 4;
        let shards: Vec<distributed::ShardSummary> = (0..4)
            .map(|r| {
                distributed::shard_summary(
                    r as u32,
                    &logits[r * vs..(r + 1) * vs],
                    r * vs,
                    &t,
                    key,
                    1,
                    step,
                )
            })
            .collect();
        let w = distributed::merge_by_mass(&shards, key, 1, step).unwrap();
        assert_eq!(d.index, w.local_sample);
        assert_eq!(d.log_z, Some(distributed::log_z(&shards)));

        let d = build_sampler("topk:k=8,tile=96")
            .unwrap()
            .sample_row(&logits, ctx)
            .unwrap();
        let tk = topk::topk_tiled(&logits, &t, key, 1, step, 8, 96);
        let s = topk::sample_from_candidates(&tk, 1.0, key, 1, step).unwrap();
        assert_eq!(d.index, s);
    }
}

/// Batch sampling through the trait uses row-indexed Philox streams, so the
/// registry's `sample_batch` agrees with the pre-trait batch entry points.
#[test]
fn batch_sampling_matches_legacy_entry_points() {
    let vocab = 128usize;
    let logits = toy_logits(4 * vocab, 4);
    let t = Transform::default();
    let key = Key::new(13, 14);

    let via_trait = build_sampler("gumbel")
        .unwrap()
        .sample_batch(&logits, vocab, &t, key, 9);
    let legacy = gumbel::sample_batch(&logits, vocab, &t, key, 9);
    for (d, g) in via_trait.iter().zip(&legacy) {
        assert_eq!(d.unwrap().index, g.unwrap().index);
    }

    let via_trait = build_sampler("multinomial")
        .unwrap()
        .sample_batch(&logits, vocab, &t, key, 9);
    let legacy = multinomial::sample_batch(&logits, vocab, &t, key, 9);
    for (d, m) in via_trait.iter().zip(&legacy) {
        assert_eq!(d.unwrap().index, m.unwrap());
    }
}

/// Temperature/masking flow through the shared `Transform` identically on
/// both sides of the boundary: a masked support restricts every sampler.
#[test]
fn transform_masking_respected_by_all_samplers() {
    let logits = toy_logits(96, 5);
    let mut bias = vec![f32::NEG_INFINITY; 96];
    for b in bias[40..56].iter_mut() {
        *b = 0.0;
    }
    let t = Transform { temperature: 0.7, bias: Some(bias) };
    for spec in SPECS {
        let s = build_sampler(spec).unwrap();
        for step in 0..25 {
            let d = s
                .sample_row(
                    &logits,
                    RowCtx { transform: &t, key: Key::new(3, 9), row: 0, step },
                )
                .unwrap();
            assert!(
                (40..56).contains(&(d.index as usize)),
                "{spec} step {step}: index {} escaped the mask",
                d.index
            );
        }
    }
}
