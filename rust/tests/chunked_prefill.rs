//! Chunked prefill + swap-tier preemption (DESIGN.md §12).
//!
//! The headline test is the acceptance criterion of the subsystem:
//! byte-identical engine output (same seeds, same `SamplerSpec`) with
//! chunked prefill at chunk 16 / 64 / beyond-prompt-length vs. whole
//! prefill — through the REAL AOT artifacts, so the multi-window
//! `prefill_cached` path, the partial-KV restore, and the Philox step
//! accounting all get exercised.  Artifact-gated like the other
//! integration suites; the accounting-level certificates run everywhere
//! through the `testutil::schedsim` harness (and in CI via
//! `repro chunk-identity`).
//!
//! CI matrix contract: `FS_TEST_PREFIX_CACHING` (`0` disables) and
//! `FS_TEST_CHUNK` (a single chunk size; unset sweeps the default set)
//! narrow the engine suites to one matrix leg.

use flashsampling::coordinator::{
    Engine, EngineConfig, EngineError, Request, SamplingParams,
};
use flashsampling::gpusim::iomodel::SwapPolicy;
use flashsampling::testutil::schedsim::{
    self, Finish, SimConfig, SimRequest,
};
use flashsampling::testutil;
use flashsampling::workload::{LengthDist, SharedPrefix, WorkloadGen};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("NOTE: artifacts/ missing; run `make artifacts`");
        None
    }
}

fn engine(cfg: EngineConfig) -> Option<Engine> {
    artifacts_dir().map(|d| Engine::new(d, cfg).unwrap())
}

/// CI matrix override: prefix caching on unless `FS_TEST_PREFIX_CACHING=0`.
fn cfg_prefix_caching() -> bool {
    std::env::var("FS_TEST_PREFIX_CACHING").map_or(true, |v| v != "0")
}

/// CI matrix override: one chunk size from `FS_TEST_CHUNK`, else the
/// default sweep (16 = multi-window, 64 = one max-bucket window, 256 =
/// beyond every prompt, i.e. window-free).
fn cfg_chunks() -> Vec<usize> {
    match std::env::var("FS_TEST_CHUNK").ok().and_then(|v| v.parse().ok()) {
        Some(c) => vec![c],
        None => vec![16, 64, 256],
    }
}

/// Shared-prefix multi-turn requests within the t=64 prefill bucket.
fn shared_prefix_requests(vocab: usize, n: usize) -> Vec<Request> {
    let mut g = WorkloadGen::new(0xC41F, 1000.0, vocab);
    g.prefix_mode = Some(SharedPrefix {
        num_prefixes: 2,
        prefix_len: 32,
        users: 4,
        turn_len: LengthDist::Fixed(4),
    });
    g.output_len = LengthDist::Uniform(3, 7);
    g.generate(n)
        .into_iter()
        .map(|s| {
            Request::new(
                s.id,
                s.prompt,
                SamplingParams {
                    max_new_tokens: s.max_new_tokens,
                    ..Default::default()
                },
            )
        })
        .collect()
}

// ---------------------------------------------------------------------
// CPU-only certificates through the schedsim harness (always run).
// ---------------------------------------------------------------------

#[test]
fn prop_chunked_schedules_replay_identically() {
    // Randomized closed-loop scripts: chunked (sticky) vs unchunked must
    // agree on every token coordinate, first-token (row, Philox step),
    // and finish state.  The harness also asserts per-step KV balance,
    // swap-ledger balance, and the no-starvation step guard on BOTH runs.
    let chunks = cfg_chunks();
    testutil::cases(24, 0x1DE7, |g| {
        let n = g.usize_in(2, 10);
        let reqs: Vec<SimRequest> = (0..n)
            .map(|i| SimRequest {
                id: i as u64,
                prompt_len: g.usize_in(4, 64),
                max_new_tokens: g.usize_in(1, 8),
                arrival_step: 0,
            })
            .collect();
        let chunk = *g.choose(&chunks);
        schedsim::assert_chunk_identity(&SimConfig::small(2048), chunk, &reqs);
    });
}

#[test]
fn prop_open_loop_schedules_with_faults_stay_balanced_and_starvation_free() {
    // Open-loop arrivals + random aborts + forced swap preemptions: the
    // harness panics on any per-step ledger imbalance, any swap-ledger
    // desync, any leak at quiescence, or a tripped starvation guard.
    testutil::cases(24, 0x0B5E, |g| {
        let n = g.usize_in(3, 12);
        let reqs: Vec<SimRequest> = (0..n)
            .map(|i| SimRequest {
                id: i as u64,
                prompt_len: g.usize_in(4, 100),
                max_new_tokens: g.usize_in(1, 10),
                arrival_step: g.usize_in(0, 12) as u64,
            })
            .collect();
        let mut cfg = SimConfig::small(g.usize_in(48, 256));
        cfg.sched.prefill_chunk_tokens = *g.choose(&[0usize, 8, 16]);
        cfg.sched.chunk_interleave = g.bool(0.5);
        cfg.swap_blocks = *g.choose(&[0usize, 16, 64]);
        for _ in 0..g.usize_in(0, 3) {
            cfg.force_abort
                .push((g.usize_in(1, 20) as u64, g.usize_in(0, n - 1) as u64));
        }
        for _ in 0..g.usize_in(0, 3) {
            cfg.force_preempt
                .push((g.usize_in(2, 20) as u64, g.usize_in(0, n - 1) as u64));
        }
        let out = schedsim::run(cfg, &reqs);
        // Every submitted request reached a terminal state.
        assert_eq!(out.len(), n);
        assert!(out.values().all(|o| o.finish.is_some()));
    });
}

#[test]
fn chunking_bounds_ttft_under_a_long_prompt_monopolist() {
    // The TTFT-under-load regression (satellite of DESIGN.md §12): short
    // prompts arriving behind a max-bucket prompt must reach their first
    // token sooner with interleaved chunking than behind an atomic whole
    // prefill.  Token-weighted time: a prefill of T tokens costs T.
    let script: Vec<SimRequest> = std::iter::once(SimRequest {
        id: 0,
        prompt_len: 64,
        max_new_tokens: 4,
        arrival_step: 0,
    })
    .chain((1..=3).map(|i| SimRequest {
        id: i,
        prompt_len: 8,
        max_new_tokens: 4,
        arrival_step: 1,
    }))
    .collect();

    let short_ttft = |cfg: SimConfig| {
        let out = schedsim::run(cfg, &script);
        assert!(out.values().all(|o| o.finish == Some(Finish::Done)));
        (1..=3)
            .map(|i| out[&i].ttft_weighted.expect("short request streamed"))
            .max()
            .unwrap()
    };

    let whole = short_ttft(SimConfig::small(2048));
    let mut chunked_cfg = SimConfig::small(2048);
    chunked_cfg.sched.prefill_chunk_tokens = 16;
    chunked_cfg.sched.chunk_interleave = true;
    let chunked = short_ttft(chunked_cfg);

    // Whole prefill makes the shorts pay the monopolist's 64-token bill
    // first; interleaved chunking bounds the head-of-line blocking to one
    // 16-token window.
    assert!(
        chunked * 2 <= whole,
        "chunking failed to separate TTFT: chunked {chunked} vs whole {whole}"
    );
}

#[test]
fn randomized_interleave_is_served_exactly_even_if_not_replay_identical() {
    // `chunk_interleave` intentionally trades replay identity for TTFT
    // (DESIGN.md §12): outcomes stay distributionally exact but
    // coordinates may move.  Serving-level guarantees must still hold —
    // every request completes with its full token budget.
    testutil::cases(12, 0x171E, |g| {
        let n = g.usize_in(2, 8);
        let reqs: Vec<SimRequest> = (0..n)
            .map(|i| SimRequest {
                id: i as u64,
                prompt_len: g.usize_in(4, 64),
                max_new_tokens: g.usize_in(1, 6),
                arrival_step: 0,
            })
            .collect();
        let mut cfg = SimConfig::small(2048);
        cfg.sched.prefill_chunk_tokens = 16;
        cfg.sched.chunk_interleave = true;
        let out = schedsim::run(cfg, &reqs);
        for r in &reqs {
            let o = &out[&r.id];
            assert_eq!(o.finish, Some(Finish::Done));
            assert_eq!(o.tokens.len(), r.max_new_tokens);
        }
    });
}

// ---------------------------------------------------------------------
// Artifact-gated engine suites.
// ---------------------------------------------------------------------

#[test]
fn chunk_on_off_byte_identity_on_shared_prefix_workload() {
    // THE acceptance criterion: for every chunk size, engine output is
    // byte-identical to whole prefill — same ids, same token bytes.
    let prefix_caching = cfg_prefix_caching();
    let run = |chunk: usize| -> Option<(Vec<(u64, Vec<i32>)>, u64)> {
        let mut e = engine(EngineConfig {
            prefix_caching,
            prefill_chunk_tokens: chunk,
            ..Default::default()
        })?;
        if chunk > 0 && e.prefill_chunk_tokens() == 0 {
            eprintln!("NOTE: no cached-prefill artifact; chunking gated off");
            return None;
        }
        let vocab = e.runtime().manifest().model.vocab;
        for r in shared_prefix_requests(vocab, 16) {
            e.submit(r).unwrap();
        }
        let mut done = e.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), 16);
        assert_eq!(e.kv_unaccounted_blocks(), 0);
        Some((
            done.into_iter().map(|c| (c.id, c.tokens)).collect(),
            e.metrics.chunked_prefill_steps,
        ))
    };
    let Some((whole, zero_windows)) = run(0) else { return };
    assert_eq!(zero_windows, 0);
    for chunk in cfg_chunks() {
        let Some((chunked, windows)) = run(chunk) else { return };
        assert_eq!(
            whole, chunked,
            "chunk={chunk} changed sampled tokens — exactness broken"
        );
        // Multi-window chunks must actually take the window path; the
        // beyond-prompt size must not (and chunk 0 — the CI matrix's
        // chunking-off leg — trivially opens none).
        if chunk > 0 && chunk < 64 {
            assert!(windows > 0, "chunk={chunk} never opened a window");
        }
        if chunk > 64 {
            assert_eq!(windows, 0, "chunk={chunk} cannot exceed the t bucket");
        }
    }
}

#[test]
fn chunking_serves_prompts_beyond_the_largest_prefill_bucket() {
    // Without chunking a 100-token prompt overflows every prefill T
    // bucket and is rejected at submit; with windows it must complete.
    let prompt: Vec<i32> = (0..100).map(|i| (i * 11 + 5) % 512).collect();
    let req = || {
        Request::new(
            7,
            prompt.clone(),
            SamplingParams { max_new_tokens: 4, ..Default::default() },
        )
    };
    let Some(mut plain) = engine(EngineConfig {
        prefix_caching: cfg_prefix_caching(),
        ..Default::default()
    }) else {
        return;
    };
    assert!(matches!(
        plain.submit(req()),
        Err(EngineError::AdmissionRejected { id: 7, .. })
    ));
    let mut chunked = engine(EngineConfig {
        prefix_caching: cfg_prefix_caching(),
        prefill_chunk_tokens: 16,
        ..Default::default()
    })
    .unwrap();
    if chunked.prefill_chunk_tokens() == 0 {
        eprintln!("NOTE: no cached-prefill artifact; chunking gated off");
        return;
    }
    chunked.submit(req()).unwrap();
    let done = chunked.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].tokens.len(), 4);
    assert!(chunked.metrics.chunked_prefill_steps >= 3, "100 tokens / 16");
    assert_eq!(chunked.kv_unaccounted_blocks(), 0);
}

#[test]
fn abort_mid_chunked_prefill_releases_partial_kv() {
    let Some(mut e) = engine(EngineConfig {
        prefix_caching: cfg_prefix_caching(),
        prefill_chunk_tokens: 16,
        ..Default::default()
    }) else {
        return;
    };
    if e.prefill_chunk_tokens() == 0 {
        eprintln!("NOTE: no cached-prefill artifact; chunking gated off");
        return;
    }
    let prompt: Vec<i32> = (0..60).map(|i| (i * 3 + 1) % 512).collect();
    e.submit(Request::new(
        1,
        prompt,
        SamplingParams { max_new_tokens: 8, ..Default::default() },
    ))
    .unwrap();
    e.submit(Request::new(
        2,
        vec![4, 5, 6, 7],
        SamplingParams { max_new_tokens: 3, ..Default::default() },
    ))
    .unwrap();
    // One step opens the head's first chunk window: request 1 now OWNS
    // registered KV while still sitting in the waiting queue.
    e.step().unwrap();
    assert!(e.metrics.chunked_prefill_steps >= 1, "no window opened");
    let c = e.abort(1).unwrap();
    assert_eq!(
        c.finish,
        flashsampling::coordinator::FinishReason::Aborted
    );
    assert!(c.tokens.is_empty(), "no token sampled mid-window");
    // The companion still completes; nothing leaked, no dangling refs.
    let done = e.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].id, 2);
    assert_eq!(e.kv_unaccounted_blocks(), 0, "mid-chunk abort leaked KV");
    assert_eq!(e.prefix_attached_refs(), 0, "dangling radix refs");
}

#[test]
fn swap_tier_preempts_and_resumes_without_losing_tokens() {
    // A pool sized to prefill three 40-token prompts (3 blocks each, 10
    // total) but NOT their decode growth (each needs a 4th block at
    // context 49): two victims must preempt to the swap tier, resume,
    // and still deliver their full 12 tokens.
    let Some(mut e) = engine(EngineConfig {
        kv_blocks: 10,
        kv_block_size: 16,
        prefix_caching: false,
        swap_blocks: 32,
        swap_policy: SwapPolicy::Always,
        ..Default::default()
    }) else {
        return;
    };
    for id in 0..3u64 {
        let prompt: Vec<i32> = (0..40).map(|i| (i * 7 + id as i32) % 512).collect();
        e.submit(Request::new(
            id,
            prompt,
            SamplingParams { max_new_tokens: 12, ..Default::default() },
        ))
        .unwrap();
    }
    let mut done = e.run_to_completion().unwrap();
    done.sort_by_key(|c| c.id);
    assert_eq!(done.len(), 3);
    for c in &done {
        assert_eq!(
            c.tokens.len(),
            12,
            "request {} lost tokens across the swap round-trip",
            c.id
        );
    }
    assert!(
        e.metrics.swap_out_blocks > 0,
        "pool pressure never reached the swap tier"
    );
    assert_eq!(
        e.metrics.swap_out_blocks, e.metrics.swap_in_blocks,
        "swapped-out blocks did not all return"
    );
    assert!(
        e.metrics.counters.get("swapped_out_seqs").copied().unwrap_or(0) >= 1
    );
    assert_eq!(e.swapped_sequences(), 0);
    assert_eq!(e.swapped_blocks(), 0);
    assert_eq!(e.kv_unaccounted_blocks(), 0);
}

#[test]
fn swap_policy_never_falls_back_to_finish_early() {
    // Same pressure shape as above, but the policy refuses to swap: the
    // engine must fall back to the legacy finish-early preemption and
    // still drain cleanly (fewer tokens, zero leaks).
    let Some(mut e) = engine(EngineConfig {
        kv_blocks: 10,
        kv_block_size: 16,
        prefix_caching: false,
        swap_blocks: 32,
        swap_policy: SwapPolicy::Never,
        ..Default::default()
    }) else {
        return;
    };
    for id in 0..3u64 {
        let prompt: Vec<i32> = (0..40).map(|i| (i * 7 + id as i32) % 512).collect();
        e.submit(Request::new(
            id,
            prompt,
            SamplingParams { max_new_tokens: 12, ..Default::default() },
        ))
        .unwrap();
    }
    let done = e.run_to_completion().unwrap();
    assert_eq!(done.len(), 3);
    assert_eq!(e.metrics.swap_out_blocks, 0, "policy Never must not swap");
    assert!(
        e.metrics
            .counters
            .get("swap_declined_by_policy")
            .copied()
            .unwrap_or(0)
            >= 1,
        "decline path never exercised"
    );
    assert_eq!(e.kv_unaccounted_blocks(), 0);
}
