//! END-TO-END driver (DESIGN.md deliverable (b)/EXPERIMENTS.md §E2E):
//! serve an open-loop batched workload through the full stack —
//! router -> continuous batcher -> prefill/decode scheduler -> PJRT
//! execution of the fused decode+FlashSampling artifacts — and report
//! latency/throughput, A/B'd against the materialized-logits baseline
//! (the paper's §4.5 protocol at this testbed's scale).
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_e2e
//! ```

use flashsampling::coordinator::{Engine, EngineConfig};
use flashsampling::sampling::SamplerSpec;
use flashsampling::workload::{LengthDist, WorkloadGen};

fn run(baseline: bool, concurrency: usize, n_requests: usize) -> anyhow::Result<()> {
    let mut engine = Engine::new(
        "artifacts",
        EngineConfig {
            sampler: if baseline {
                SamplerSpec::Multinomial
            } else {
                SamplerSpec::default()
            },
            max_concurrency: concurrency,
            ..Default::default()
        },
    )?;
    let vocab = engine.runtime().manifest().model.vocab;
    // Poisson arrivals at rate = concurrency (the paper's protocol:
    // --request-rate=B with --max-concurrency=B), from a mixed-temperature
    // client population (per-row tau batches them together).
    let mut gen = WorkloadGen::new(42, concurrency as f64, vocab);
    gen.temperature_choices = vec![0.5, 0.7, 1.0, 1.3];
    gen.prompt_len = LengthDist::Uniform(8, 48);
    gen.output_len = LengthDist::Uniform(16, 48);
    let reqs = gen.generate(n_requests);
    let done = engine.serve(reqs)?;
    let m = &engine.metrics;
    println!(
        "| {} | {concurrency} | {} | {} | {:.1} | {:.2} | {:.2} | {:.2} |",
        if baseline { "baseline" } else { "FlashSampling" },
        done.len(),
        m.tokens_generated,
        m.median_ttft().map(|d| d.as_secs_f64() * 1e3).unwrap_or(f64::NAN),
        m.median_tpot().map(|d| d.as_secs_f64() * 1e3).unwrap_or(f64::NAN),
        m.throughput_tps(),
        m.mean_batch(),
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!(
        "## serve_e2e — open-loop serving on the tiny transformer \
         (4L x d256 x V2048, CPU PJRT)\n"
    );
    println!("| sampler | concurrency | reqs | tokens | median TTFT ms | median TPOT ms | tok/s | mean batch |");
    println!("|---|---|---|---|---|---|---|---|");
    for concurrency in [2usize, 4, 8] {
        for baseline in [false, true] {
            run(baseline, concurrency, 24)?;
        }
    }
    println!("\n(TPOT deltas on this CPU testbed reflect XLA-CPU kernel");
    println!("differences, not HBM traffic — the GPU-scale TPOT deltas are");
    println!("modeled in `flashsampling repro table7/table8`.)");
    Ok(())
}
