//! Regenerate every table and figure of the paper's evaluation into
//! `results/` (the DESIGN.md §5 experiment index maps ids to artifacts).
//!
//! ```sh
//! cargo run --release --example paper_tables
//! ```

fn main() -> anyhow::Result<()> {
    let out = std::path::Path::new("results");
    flashsampling::repro::run_all(out)?;
    // Statistical verifications (real sampling, §4.6).
    for id in flashsampling::repro::STATS {
        let md = flashsampling::repro::run(id, out)?;
        println!("=== {id} ===\n{md}");
    }
    println!("wrote results/*.md");
    Ok(())
}
