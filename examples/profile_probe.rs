
use flashsampling::coordinator::{Engine, EngineConfig, Request, SamplingParams};

fn main() -> anyhow::Result<()> {
    let mut engine = Engine::new("artifacts", EngineConfig::default())?;
    for i in 0..8u64 {
        engine.submit(Request::new(
            i,
            vec![1 + i as i32; 8],
            SamplingParams { max_new_tokens: 200, ..Default::default() },
        ))?;
    }
    for _ in 0..2 { engine.step()?; } // prefill
    let mut times = Vec::new();
    for _ in 0..20 {
        let t = std::time::Instant::now();
        engine.step()?;
        times.push(t.elapsed().as_micros() as u64);
    }
    println!("per-step us: {times:?}");
    let n = 20u64;
    let mut keys: Vec<_> = engine.metrics.counters.iter().collect();
    keys.sort();
    for (k, v) in keys {
        if k.ends_with("_us") { println!("{k}: {} us/step(avg over bumps)", v / n); }
    }
    Ok(())
}
