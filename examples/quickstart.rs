//! Quickstart: load the AOT artifacts and draw exact samples through the
//! fused FlashSampling kernel, then cross-check against the native
//! Gumbel-Max oracle.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use flashsampling::runtime::{Runtime, Tensor};
use flashsampling::sampling::{gumbel, Key, Transform};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new("artifacts")?;
    println!("platform: {}", rt.platform());

    // Shapes come from the artifact manifest (fixed at AOT time).
    let (b, d, v) = (4usize, 256usize, 2048usize);
    let artifact = format!("flash_sample_b{b}_d{d}_v{v}");

    // Any hidden states / LM-head weights; here deterministic toys.
    let h: Vec<f32> = (0..b * d).map(|i| ((i % 7) as f32 - 3.0) * 0.1).collect();
    let w: Vec<f32> = (0..v * d).map(|i| ((i % 11) as f32 - 5.0) * 0.02).collect();
    let key = Key::from_seed(2026);

    // One call = LM-head matmul + Gumbel noise + tiled argmax, no [B,V]
    // logits tensor ever materialized (that's the paper).
    let out = rt.run(
        &artifact,
        &[
            Tensor::F32(h.clone(), vec![b, d]),
            Tensor::F32(w.clone(), vec![v, d]),
            Tensor::seed(key),
            Tensor::scalar_u32(0),            // decode step
            Tensor::F32(vec![0.8; b], vec![b]), // per-row temperature (ABI v2)
        ],
    )?;
    let samples = out[0].as_i32()?;
    println!("fused samples: {samples:?}");

    // Exactness check: the same draw via materialized logits in Rust.
    let mut logits = vec![0.0f32; b * v];
    for bi in 0..b {
        for vi in 0..v {
            logits[bi * v + vi] =
                (0..d).map(|di| h[bi * d + di] * w[vi * d + di]).sum();
        }
    }
    let t = Transform::with_temperature(0.8);
    let oracle = gumbel::sample_batch(&logits, v, &t, key, 0);
    for (bi, o) in oracle.iter().enumerate() {
        assert_eq!(samples[bi] as u32, o.unwrap().index);
    }
    println!("pathwise exactness vs native Gumbel-Max: OK");
    Ok(())
}
