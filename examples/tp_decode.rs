//! Tensor-parallel decoding demo: vocabulary-sharded ranks (one PJRT
//! runtime per thread), FlashSampling P2P-fanout merge vs the all-gather
//! baselines, with wire-byte accounting (paper §3.2 / Alg. I.4).
//!
//! ```sh
//! make artifacts && cargo run --release --example tp_decode
//! ```

use flashsampling::sampling::philox::{self, Key};
use flashsampling::tp::{Strategy, TpConfig, TpOrchestrator};

fn randn(n: usize, seed: u64, scale: f32) -> Vec<f32> {
    let key = Key::from_seed(seed);
    (0..n)
        .map(|i| {
            let s: f32 = (0..4)
                .map(|j| philox::uniform_at(key, i as u32, j, 3, 1))
                .sum();
            (s - 2.0) * scale * 1.7320508
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let (b, d, v) = (4usize, 256usize, 2048usize);
    let w = randn(v * d, 1, 0.05);
    let h = randn(b * d, 2, 0.5);

    for n_ranks in [2usize, 4] {
        println!("=== TP = {n_ranks} ===");
        let mut orch = TpOrchestrator::new(
            TpConfig {
                artifacts_dir: "artifacts".into(),
                n_ranks,
                batch: b,
                d_model: d,
                vocab: v,
                seed: 99,
            },
            &w,
        )?;
        let mut last = None;
        for (strategy, name) in [
            (Strategy::P2pFanout, "FlashSampling P2P fan-out"),
            (Strategy::AllGatherGumbel, "all-gather + Gumbel-Max"),
            (Strategy::AllGatherMultinomial, "all-gather + multinomial"),
        ] {
            // tau: [B] — uniform here; per-row in mixed-client serving.
            let out = orch.step(&h, 0, &vec![1.0; b], strategy)?;
            println!(
                "  {name:<32} samples {:?}  wire bytes {:>8}",
                out.samples, out.wire_bytes
            );
            if strategy == Strategy::P2pFanout {
                last = Some(out.samples.clone());
            } else if strategy == Strategy::AllGatherGumbel {
                // Same Philox streams => pathwise identical to the fan-out.
                assert_eq!(last.as_deref(), Some(out.samples.as_slice()));
            }
        }
        let stats = orch.link_stats();
        for (r, s) in stats.iter().enumerate() {
            println!("  rank {r}: {} msgs, {} bytes total", s.messages, s.bytes);
        }
        orch.shutdown()?;
    }
    println!("fan-out merge == all-gather Gumbel-Max (exactness across strategies): OK");
    Ok(())
}
