"""Counter-based Philox4x32-10 RNG, implemented in pure jnp uint32 ops.

FlashSampling requires every Gumbel variate to be a deterministic function of
a key and the *logical output position* (b, i) (paper Appendix C: "RNG streams
are indexed by the logical output position (b, i) using a counter-based RNG
(e.g. Philox)").  Position-indexed RNG is what makes the fused tiled kernel
*pathwise* exact: any tiling of the vocabulary sees the same perturbed scores,
so the tile-wise reduction (Lemma D.5) returns the identical sample.

This module implements Philox4x32 with 10 rounds (Salmon et al., SC'11) using
only 32-bit integer ops so it lowers cleanly inside Pallas interpret-mode
kernels and through StableHLO -> XLA CPU without requiring x64 mode.  The
identical algorithm is implemented in Rust (`rust/src/sampling/philox.rs`);
cross-language test vectors live in `python/tests/test_philox.py` and
`rust/src/sampling/philox.rs::tests`.

Counter layout for FlashSampling draws (one 128-bit counter per draw):

    ctr = (i, b, stream, step)    key = (seed_lo, seed_hi)

  * i       vocabulary index (column) of the perturbed logit
  * b       row (batch element)
  * stream  domain separator: 0 = Gumbel epilogue, 1 = baseline row uniforms,
            2 = outer group/rank selection, 3 = reserved
  * step    decode step, so each autoregressive step draws fresh noise

The first output word x0 is mapped to the open interval (0, 1) via
u = (x0 + 1) / (2^32 + 1)  (paper Appendix J) and then g = -log(-log u).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Philox4x32 round constants (Salmon et al. 2011).
PHILOX_M0 = np.uint32(0xD2511F53)
PHILOX_M1 = np.uint32(0xCD9E8D57)
PHILOX_W0 = np.uint32(0x9E3779B9)  # golden-ratio key bump
PHILOX_W1 = np.uint32(0xBB67AE85)  # sqrt(3)-1 key bump

# Stream domain separators (must match rust/src/sampling/philox.rs).
STREAM_GUMBEL = 0
STREAM_ROW_UNIFORM = 1
STREAM_GROUP_SELECT = 2


def _u32(x):
    return jnp.asarray(x, dtype=jnp.uint32)


def _mulhilo32(a, b):
    """Full 32x32 -> 64-bit product as (hi, lo) uint32 words.

    Implemented with 16-bit limbs so no 64-bit integer type is needed (jax
    runs in the default 32-bit mode and Pallas interpret handles u32 natively).
    """
    a = _u32(a)
    b = _u32(b)
    mask = np.uint32(0xFFFF)
    al = a & mask
    ah = a >> 16
    bl = b & mask
    bh = b >> 16
    # Partial products; each fits in 32 bits (16x16 -> <=32 bits).
    ll = al * bl
    lh = al * bh
    hl = ah * bl
    hh = ah * bh
    # Carry assembly: mid accumulates bits [16, 48) of the product.
    mid = (ll >> 16) + (lh & mask) + (hl & mask)
    lo = (ll & mask) | (mid << 16)
    hi = hh + (lh >> 16) + (hl >> 16) + (mid >> 16)
    return hi, lo


def _philox_round(c0, c1, c2, c3, k0, k1):
    hi0, lo0 = _mulhilo32(PHILOX_M0, c0)
    hi1, lo1 = _mulhilo32(PHILOX_M1, c2)
    n0 = hi1 ^ c1 ^ k0
    n1 = lo1
    n2 = hi0 ^ c3 ^ k1
    n3 = lo0
    return n0, n1, n2, n3


def philox4x32(c0, c1, c2, c3, k0, k1, rounds: int = 10):
    """Philox4x32 block cipher: 128-bit counter -> 128-bit random output.

    All inputs may be arrays (broadcast together); returns 4 uint32 arrays.
    """
    c0, c1, c2, c3 = _u32(c0), _u32(c1), _u32(c2), _u32(c3)
    k0, k1 = _u32(k0), _u32(k1)
    c0, c1, c2, c3 = jnp.broadcast_arrays(c0, c1, c2, c3)
    for r in range(rounds):
        c0, c1, c2, c3 = _philox_round(c0, c1, c2, c3, k0, k1)
        if r + 1 < rounds:
            k0 = k0 + PHILOX_W0
            k1 = k1 + PHILOX_W1
    return c0, c1, c2, c3


def uniform_open01(x0):
    """Map a uint32 word to the open interval (0, 1).

    The paper's fallback u = (r+1)/(2^32+1) (Appendix J) is only open in
    exact arithmetic: in FP32 any r >= 2^32 - 2^8 rounds to u = 1.0 and the
    Gumbel transform blows up.  We therefore use a top-23-bit mapping
    u = (r>>9 + 0.5) * 2^-23: (r>>9) + 0.5 needs at most 24 mantissa bits so
    it is exactly representable in FP32, confining u to [2^-24, 1 - 2^-24] —
    satisfying the same "avoid u = 0 or u = 1" requirement the appendix
    states.  The Rust runtime uses the identical mapping
    (rust/src/sampling/philox.rs).
    """
    x0 = _u32(x0)
    return ((x0 >> np.uint32(9)).astype(jnp.float32) + np.float32(0.5)) * np.float32(
        1.0 / 8388608.0
    )


def gumbel_at(i, b, step, seed_lo, seed_hi):
    """Standard Gumbel(0,1) noise for logical position (b, i) at decode `step`.

    Deterministic in (i, b, step, seed); independent across distinct counters.
    FP32 throughout (paper Appendix C: noise generated in FP32).
    """
    x0, _, _, _ = philox4x32(i, b, STREAM_GUMBEL, step, seed_lo, seed_hi)
    u = uniform_open01(x0)
    return -jnp.log(-jnp.log(u))


def uniform_at(i, b, step, seed_lo, seed_hi, stream=STREAM_ROW_UNIFORM):
    """Uniform(0,1) draw for position (b, i); used by the baseline sampler
    (inverse-CDF search) and the grouped outer selection."""
    x0, _, _, _ = philox4x32(i, b, stream, step, seed_lo, seed_hi)
    return uniform_open01(x0)
