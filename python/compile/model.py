"""L2: the serving model — a small decoder-only transformer in JAX.

This is the compute graph the Rust coordinator drives at decode time.  It is
deliberately small (the box has no GPU; the paper's Qwen3/Llama models are
substituted per DESIGN.md §2) but architecturally real: RMSNorm, RoPE
multi-head attention with an in-graph KV cache, SwiGLU FFN, and an LM head
whose sampling step is the FlashSampling Pallas kernel fused into the same
HLO module — so the artifact the Rust side executes performs
"decode step -> LM head -> exact sample" with no logits materialization and
no host round-trip between projection and sampling.

Everything here is build-time only.  `aot.py` lowers:
  * prefill_T{T}:        tokens -> KV cache + last hidden
  * decode_step:         (kv, pos, token) -> (kv', hidden)
  * decode_and_sample:   decode_step + flash_sample fused
  * decode_and_sample_sub: decode_step + candidate-tile flash_sample (§16)
  * decode_and_sample_baseline: decode_step + materialized multinomial
  * lm heads / shard kernels at benchmark shapes

Weights are generated deterministically from a seed and exported as raw
binaries next to the HLO artifacts (manifest.json lists shapes); the Rust
runtime loads them and passes them as runtime parameters, keeping HLO text
small and the weight path dtype-exact.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import flash_sampling as fs
from compile.kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters for the tiny serving model."""

    vocab: int = 2048
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    ffn: int = 512
    max_seq: int = 256
    rope_base: float = 10000.0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_shapes(self) -> Dict[str, Tuple[int, ...]]:
        """Flat name -> shape map; the manifest/weight-export contract."""
        s: Dict[str, Tuple[int, ...]] = {"embed": (self.vocab, self.d_model)}
        for l in range(self.n_layers):
            p = f"layers.{l}."
            s[p + "ln1"] = (self.d_model,)
            s[p + "wq"] = (self.d_model, self.d_model)
            s[p + "wk"] = (self.d_model, self.d_model)
            s[p + "wv"] = (self.d_model, self.d_model)
            s[p + "wo"] = (self.d_model, self.d_model)
            s[p + "ln2"] = (self.d_model,)
            s[p + "w_gate"] = (self.d_model, self.ffn)
            s[p + "w_up"] = (self.d_model, self.ffn)
            s[p + "w_down"] = (self.ffn, self.d_model)
        s["final_norm"] = (self.d_model,)
        s["lm_head"] = (self.vocab, self.d_model)
        return s

    def param_order(self):
        """Canonical parameter ordering — the positional ABI shared with the
        Rust runtime (artifacts take params in this exact order)."""
        return sorted(self.param_shapes().keys())


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jax.Array]:
    """Deterministic scaled-normal init (fixed weights; the model is not
    trained — §4.6's exactness claims are about sampling, not quality)."""
    shapes = cfg.param_shapes()
    params = {}
    for name in cfg.param_order():
        shape = shapes[name]
        key = jax.random.fold_in(jax.random.PRNGKey(seed), hash(name) & 0x7FFFFFFF)
        if name.endswith(("ln1", "ln2", "final_norm")):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-1] if len(shape) > 1 else shape[0]
            params[name] = (
                jax.random.normal(key, shape, jnp.float32) / np.sqrt(fan_in)
            )
    return params


def rmsnorm(x, scale, eps=1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def rope(x, positions, base):
    """Rotary embedding. x: [..., T, H, Dh], positions: [..., T]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))
    theta = positions[..., None].astype(jnp.float32) * freqs  # [..., T, half]
    cos = jnp.cos(theta)[..., None, :]  # broadcast over heads
    sin = jnp.sin(theta)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention_decode(q, k_cache, v_cache, pos):
    """Single-position attention against the cache.

    q: [B, H, Dh]; caches: [B, H, S, Dh]; pos: [B] current position (the new
    token's K/V must already be written at index pos).
    """
    s = k_cache.shape[2]
    scores = jnp.einsum("bhd,bhsd->bhs", q, k_cache) / np.sqrt(q.shape[-1])
    span = jnp.arange(s)[None, None, :] <= pos[:, None, None]
    scores = jnp.where(span, scores, -jnp.inf)
    attn = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", attn, v_cache)


def decode_step(cfg: ModelConfig, params, kv_k, kv_v, pos, token):
    """One autoregressive decode step.

    Args:
      kv_k, kv_v: [L, B, H, S, Dh] caches.
      pos: [B] i32 — position of `token` in each sequence.
      token: [B] i32 — current input token ids.

    Returns (kv_k', kv_v', hidden [B, D]).
    """
    b = token.shape[0]
    x = params["embed"][token]  # [B, D]
    new_k, new_v = [], []
    for l in range(cfg.n_layers):
        p = f"layers.{l}."
        h = rmsnorm(x, params[p + "ln1"])
        q = (h @ params[p + "wq"]).reshape(b, cfg.n_heads, cfg.head_dim)
        k = (h @ params[p + "wk"]).reshape(b, cfg.n_heads, cfg.head_dim)
        v = (h @ params[p + "wv"]).reshape(b, cfg.n_heads, cfg.head_dim)
        q = rope(q[:, None], pos[:, None], cfg.rope_base)[:, 0]
        k = rope(k[:, None], pos[:, None], cfg.rope_base)[:, 0]

        # Scatter this step's K/V into the cache at pos (per row).
        # vmapped dynamic_update_slice lowers to a scatter that writes only
        # B*H*Dh elements — a full-cache onehot blend here costs ~2x the
        # whole cache in read+write traffic per layer and dominated the
        # decode artifact's CPU time (EXPERIMENTS.md §Perf L2).
        def put(cache, val):
            # cache: [B, H, S, Dh]; val: [B, H, Dh]
            def upd(c, v, p):
                return jax.lax.dynamic_update_slice(
                    c, v[:, None, :].astype(c.dtype), (0, p, 0)
                )
            return jax.vmap(upd)(cache, val, pos)

        kc = put(kv_k[l], k)
        vc = put(kv_v[l], v)
        new_k.append(kc)
        new_v.append(vc)

        o = _attention_decode(q, kc, vc, pos).reshape(b, cfg.d_model)
        x = x + o @ params[p + "wo"]
        h2 = rmsnorm(x, params[p + "ln2"])
        x = x + (
            jax.nn.silu(h2 @ params[p + "w_gate"]) * (h2 @ params[p + "w_up"])
        ) @ params[p + "w_down"]
    hidden = rmsnorm(x, params["final_norm"])
    return jnp.stack(new_k), jnp.stack(new_v), hidden


def prefill(cfg: ModelConfig, params, tokens, lengths):
    """Process a padded prompt batch, building the KV cache.

    Args:
      tokens: [B, T] i32, padded with anything beyond lengths.
      lengths: [B] i32 true prompt lengths (>=1).

    Returns (kv_k, kv_v [L, B, H, S, Dh], hidden [B, D] at the last real
    position — the state from which the first output token is sampled).
    """
    b, t = tokens.shape
    x = params["embed"][tokens]  # [B, T, D]
    positions = jnp.arange(t)[None, :] * jnp.ones((b, 1), jnp.int32)
    kmask = jnp.arange(t)[None, :] < lengths[:, None]  # [B, T] real tokens
    causal = jnp.tril(jnp.ones((t, t), bool))
    kv_k, kv_v = [], []
    for l in range(cfg.n_layers):
        p = f"layers.{l}."
        h = rmsnorm(x, params[p + "ln1"])
        q = (h @ params[p + "wq"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
        k = (h @ params[p + "wk"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
        v = (h @ params[p + "wv"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
        q = rope(q, positions, cfg.rope_base)
        k = rope(k, positions, cfg.rope_base)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(cfg.head_dim)
        mask = causal[None, None] & kmask[:, None, None, :]
        scores = jnp.where(mask, scores, -jnp.inf)
        attn = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", attn, v).reshape(b, t, cfg.d_model)
        x = x + o @ params[p + "wo"]
        h2 = rmsnorm(x, params[p + "ln2"])
        x = x + (
            jax.nn.silu(h2 @ params[p + "w_gate"]) * (h2 @ params[p + "w_up"])
        ) @ params[p + "w_down"]
        # Cache layout: [B, H, S, Dh] with prompt K/V in slots [0, T).
        kc = jnp.zeros((b, cfg.n_heads, cfg.max_seq, cfg.head_dim), jnp.float32)
        vc = jnp.zeros_like(kc)
        kc = kc.at[:, :, :t, :].set(jnp.transpose(k, (0, 2, 1, 3)))
        vc = vc.at[:, :, :t, :].set(jnp.transpose(v, (0, 2, 1, 3)))
        # Slots in [length, T) hold padded-token K/V, but they are never
        # attended: prefill masks them via kmask, and decode overwrites slot
        # `pos` before reading it (continuation starts at pos = length), so
        # every slot <= pos is always real by the time it enters the span.
        kv_k.append(kc)
        kv_v.append(vc)
    hidden_all = rmsnorm(x, params["final_norm"])  # [B, T, D]
    last = jnp.take_along_axis(
        hidden_all, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1
    )[:, 0]
    return jnp.stack(kv_k), jnp.stack(kv_v), last


def prefill_cached(cfg: ModelConfig, params, kv_k, kv_v, offset, tokens, lengths):
    """Suffix prefill over a prefix-cached KV state (automatic prefix
    caching, DESIGN.md §10).

    Per row `b`, positions `[0, offset[b])` of `kv_k`/`kv_v` already hold
    the KV of a cached prompt prefix (byte-identical to what full prefill
    would compute — the prefix cache restores the original bytes); `tokens`
    carries only the uncached suffix.  Each suffix position is embedded at
    its *absolute* position `offset + i` (RoPE), its K/V is scattered into
    the cache there, and attention spans every cache slot `<=` its absolute
    position — the cached prefix plus the in-suffix causal triangle.

    Exactness: in exact arithmetic this is literally full prefill with the
    prefix computation replaced by its stored result; on XLA CPU the
    outputs are **bitwise identical** to `prefill` for the same prompts
    (asserted by python/tests/test_prefix_cache.py, including across T
    buckets and at offset == 0), which is what makes the engine's
    caching-on/off token identity exact rather than approximate.

    Args:
      kv_k, kv_v: [L, B, H, S, Dh] caches carrying the cached prefixes.
      offset: [B] i32 cached prefix lengths (0 = no cached prefix).
      tokens: [B, T] i32 suffix tokens, padded beyond lengths.
      lengths: [B] i32 true suffix lengths (>= 1).

    Returns (kv_k', kv_v' [L, B, H, S, Dh], hidden [B, D] at the last real
    suffix position — the state the first output token samples from).
    """
    b, t = tokens.shape
    s = cfg.max_seq
    x = params["embed"][tokens]  # [B, T, D]
    positions = offset[:, None] + jnp.arange(t)[None, :]  # [B, T] absolute
    new_k, new_v = [], []
    for l in range(cfg.n_layers):
        p = f"layers.{l}."
        h = rmsnorm(x, params[p + "ln1"])
        q = (h @ params[p + "wq"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
        k = (h @ params[p + "wk"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
        v = (h @ params[p + "wv"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
        q = rope(q, positions, cfg.rope_base)
        k = rope(k, positions, cfg.rope_base)

        # Scatter the suffix K/V into the cache at [offset, offset + T).
        # Padded positions beyond lengths land at dead slots: they sit past
        # every real query's span this call, and later decode steps
        # overwrite slot `pos` before reading it (same argument as
        # prefill's padding note).
        def put(cache, val):
            # cache: [B, H, S, Dh]; val: [B, T, H, Dh]
            def upd(c, vv, off):
                return jax.lax.dynamic_update_slice(
                    c, jnp.transpose(vv, (1, 0, 2)).astype(c.dtype), (0, off, 0)
                )
            return jax.vmap(upd)(cache, val, offset)

        kc = put(kv_k[l], k)
        vc = put(kv_v[l], v)
        new_k.append(kc)
        new_v.append(vc)

        # Query at absolute position p_i attends to every cache slot
        # j <= p_i: cached prefix slots plus the causal in-suffix span.
        scores = jnp.einsum("bqhd,bhsd->bhqs", q, kc) / np.sqrt(cfg.head_dim)
        span = jnp.arange(s)[None, None, None, :] <= positions[:, None, :, None]
        scores = jnp.where(span, scores, -jnp.inf)
        attn = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhqs,bhsd->bqhd", attn, vc).reshape(b, t, cfg.d_model)
        x = x + o @ params[p + "wo"]
        h2 = rmsnorm(x, params[p + "ln2"])
        x = x + (
            jax.nn.silu(h2 @ params[p + "w_gate"]) * (h2 @ params[p + "w_up"])
        ) @ params[p + "w_down"]
    hidden_all = rmsnorm(x, params["final_norm"])  # [B, T, D]
    last = jnp.take_along_axis(
        hidden_all, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1
    )[:, 0]
    return jnp.stack(new_k), jnp.stack(new_v), last


def decode_and_sample(cfg: ModelConfig, params, kv_k, kv_v, pos, token, seed, step,
                      temperature, tile_v=fs.DEFAULT_TILE_V):
    """Fused decode step + FlashSampling LM head (the serving hot path).

    `temperature` is a [B] per-row vector (scalars broadcast) — the
    tau: [B] ABI that lets mixed-temperature requests share a batch.
    """
    kv_k, kv_v, hidden = decode_step(cfg, params, kv_k, kv_v, pos, token)
    out = fs.flash_sample(
        hidden, params["lm_head"], seed, step, temperature, tile_v=tile_v
    )
    return kv_k, kv_v, out.sample


def decode_and_sample_sub(cfg: ModelConfig, params, kv_k, kv_v, pos, token,
                          seed, step, temperature, tiles,
                          tile_v=fs.DEFAULT_TILE_V):
    """Fused decode step + candidate-tile FlashSampling (DESIGN.md §16).

    Runs the LM head only over the candidate vocab tiles in `tiles`
    ([S] i32, -1 = unused slot) and additionally returns the candidate
    winner's perturbed score and the per-row hidden norm — the two runtime
    inputs of the host-side exactness certificate.  Philox coordinates are
    global, so whenever the certificate admits the skip the sampled token is
    bit-identical to `decode_and_sample` at the same (seed, step).
    """
    kv_k, kv_v, hidden = decode_step(cfg, params, kv_k, kv_v, pos, token)
    sample, max_score, h_norm = fs.subvocab_candidates(
        hidden, params["lm_head"], tiles, seed, step, temperature, tile_v=tile_v
    )
    return kv_k, kv_v, sample, max_score, h_norm


def decode_and_sample_baseline(cfg: ModelConfig, params, kv_k, kv_v, pos, token,
                               seed, step, temperature):
    """Decode step + the paper's baseline pipeline (materialized logits,
    softmax, prefix-sum, inverse-CDF) — Algorithm A.1 as one artifact."""
    kv_k, kv_v, hidden = decode_step(cfg, params, kv_k, kv_v, pos, token)
    sample = kref.multinomial_sample(
        hidden, params["lm_head"], seed, step, temperature
    )
    return kv_k, kv_v, sample


def sample_from_hidden(cfg: ModelConfig, params, hidden, seed, step, temperature,
                       tile_v=fs.DEFAULT_TILE_V):
    """LM head + FlashSampling from a precomputed hidden state (used after
    prefill to sample the first output token; `temperature` is per-row)."""
    out = fs.flash_sample(
        hidden, params["lm_head"], seed, step, temperature, tile_v=tile_v
    )
    return out.sample
