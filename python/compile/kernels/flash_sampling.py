"""FlashSampling fused Pallas kernel (paper Algorithm 1).

Stage 1 runs on a (batch-tile x vocab-tile) grid.  Each grid cell:
  1. computes the logit tile Y[bt, vt] = H[bt, :] @ W[vt, :]^T on chip,
     accumulating in f32 (paper Appendix C),
  2. applies deterministic transforms (temperature, optional bias/mask),
  3. draws position-indexed Gumbel noise with Philox4x32 (Appendix C/J),
  4. reduces the tile to one (max perturbed score, global argmax) candidate
     per row and writes only that candidate to the output buffers.

Stage 2 is a tiny argmax over the [B, n_vocab_tiles] candidate buffer
(Lemma D.5 makes this pathwise exact).  The full [B, V] logits tensor is
never materialized — the HBM side of the kernel writes O(B * n_tiles).

Hardware adaptation (DESIGN.md §8): the paper's CUDA threadblock/SMEM tiling
becomes a Pallas grid over BlockSpecs; the HBM->VMEM pipeline plays the role
of cp.async staging, the MXU does the f32-accumulated matmul, and the VPU
does the epilogue (transform + Gumbel + argmax).  `interpret=True` is
mandatory on this CPU-only box — real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.

Grouped outputs: with `want_lmass=True` the kernel additionally emits the
per-tile log-mass L_t = logsumexp(Y[b, tile]) used by the grouped / online /
distributed variants (Lemmas D.1-D.3) and by the optional log-normalizer
output (Appendix L).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile import philox

NEG_INF = float('-inf')

# Default tile shapes.  On a real TPU the vocab tile is sized so that the
# W tile (tile_v x D bf16) plus the H tile fits in VMEM with room for
# double-buffering; see DESIGN.md §7 and `vmem_footprint_bytes` below.
DEFAULT_TILE_V = 512
DEFAULT_TILE_B = 8


class FlashSampleOut(NamedTuple):
    """Outputs of the fused two-stage sampler."""

    sample: jax.Array  # [B] i32 — exact sample from Cat(softmax(transform(Y)))
    max_score: jax.Array  # [B] f32 — winning perturbed score (diagnostic)
    log_z: Optional[jax.Array]  # [B] f32 log-normalizer, if want_lmass


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def vmem_footprint_bytes(
    tile_b: int, tile_v: int, d: int, in_dtype=jnp.bfloat16, buffers: int = 2
) -> int:
    """Estimated VMEM bytes for one grid cell (perf model, DESIGN.md §7).

    W tile dominates: tile_v x D input-dtype elements; H tile is tile_b x D;
    the f32 accumulator is tile_b x tile_v; candidate outputs are negligible.
    `buffers=2` accounts for Pallas double-buffering of the streamed W tile.
    """
    itemsize = jnp.dtype(in_dtype).itemsize
    w_tile = tile_v * d * itemsize * buffers
    h_tile = tile_b * d * itemsize
    acc = tile_b * tile_v * 4
    epilogue = tile_b * tile_v * 4  # perturbed scores before the reduce
    return w_tile + h_tile + acc + epilogue


def _stage1_kernel(
    h_ref,
    w_ref,
    seed_ref,
    step_ref,
    tau_ref,
    bias_ref,
    m_ref,
    idx_ref,
    lmass_ref,
    logits_ref,
    *,
    vocab: int,
    tile_v: int,
    want_lmass: bool,
    store_logits: bool,
):
    """One (batch-tile, vocab-tile) grid cell of Stage 1."""
    vt = pl.program_id(1)
    bt = pl.program_id(0)
    tile_b = h_ref.shape[0]

    # --- tiled matmul over D, f32 accumulation, kept on chip (Alg.1 line 1).
    h = h_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    y = jax.lax.dot_general(
        h,
        w,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [tile_b, tile_v]

    # --- deterministic transforms (Alg.1 line 3).
    # tau is per-row (the tau: [B] ABI): this tile sees its batch-tile's
    # slice, broadcast over the vocab axis.
    tau = tau_ref[...]
    y = y / tau[:, None] + bias_ref[...][None, :]

    # Global coordinates of this tile's elements.
    i_global = (vt * tile_v + jnp.arange(tile_v, dtype=jnp.int32))[None, :]
    b_global = (bt * tile_b + jnp.arange(tile_b, dtype=jnp.int32))[:, None]
    valid = i_global < vocab  # vocab padding never wins nor carries mass
    y = jnp.where(valid, y, NEG_INF)

    if store_logits:
        # Logits-store ablation (paper Appendix K): one flag writes the
        # [B, V] tile back to HBM with no other change to the kernel.
        logits_ref[...] = y

    # --- position-indexed Gumbel perturbation (Alg.1 lines 4-5).
    g = philox.gumbel_at(
        i_global.astype(jnp.uint32),
        jnp.broadcast_to(b_global, (tile_b, tile_v)).astype(jnp.uint32),
        step_ref[0],
        seed_ref[0],
        seed_ref[1],
    )
    s = jnp.where(valid, y + g, NEG_INF)

    # --- tile-local reduction: one candidate per row (Alg.1 lines 7-9).
    m_ref[...] = jnp.max(s, axis=1, keepdims=True)
    local = jnp.argmax(s, axis=1).astype(jnp.int32)
    idx_ref[...] = (vt * tile_v + local)[:, None]

    if want_lmass:
        # Group log-mass L_t = logsumexp(y) over the tile (Lemma D.1).
        ymax = jnp.max(y, axis=1, keepdims=True)
        safe = jnp.where(jnp.isfinite(ymax), ymax, 0.0)
        lse = safe[:, 0] + jnp.log(jnp.sum(jnp.exp(y - safe), axis=1))
        lmass_ref[...] = jnp.where(jnp.isfinite(ymax[:, 0]), lse, NEG_INF)[:, None]


def stage1_candidates(
    h,
    w,
    seed,
    step=0,
    temperature=1.0,
    bias=None,
    *,
    tile_b: int = DEFAULT_TILE_B,
    tile_v: int = DEFAULT_TILE_V,
    want_lmass: bool = False,
    store_logits: bool = False,
    interpret: bool = True,
):
    """Run Stage 1: returns per-vocab-tile candidates.

    Args:
      h: [B, D] hidden states (any float dtype; accumulated in f32).
      w: [V, D] LM-head weights.
      seed: uint32[2] RNG key.
      step: int32 decode step (fresh noise per autoregressive step).
      temperature: softmax temperature(s) tau > 0 — a scalar (uniform batch)
        or a [B] vector (per-row tau, the ABI v2 form); scalars broadcast.
      bias: optional [V] additive logit bias (also used for -inf masking).

    Returns:
      (m [B, n_tiles] f32, idx [B, n_tiles] i32, lmass [B, n_tiles] f32|None,
       logits [B, n_tiles*tile_v] f32|None)
    """
    batch, d = h.shape
    vocab, d2 = w.shape
    assert d == d2, (d, d2)
    tile_b = min(tile_b, batch)
    tile_v = min(tile_v, vocab)
    nb = _ceil_div(batch, tile_b)
    nv = _ceil_div(vocab, tile_v)

    # Pad rows/vocab up to tile multiples.  Padded vocab entries are masked
    # inside the kernel via the i_global < vocab predicate; padded batch rows
    # are dropped after the call.
    pb = nb * tile_b - batch
    pv = nv * tile_v - vocab
    if pb:
        h = jnp.pad(h, ((0, pb), (0, 0)))
    if pv:
        w = jnp.pad(w, ((0, pv), (0, 0)))
    if bias is None:
        bias_arr = jnp.zeros((nv * tile_v,), jnp.float32)
    else:
        bias_arr = jnp.pad(bias.astype(jnp.float32), (0, pv))

    seed = jnp.asarray(seed, jnp.uint32).reshape(2)
    step_arr = jnp.asarray(step, jnp.uint32).reshape(1)
    # tau: [B] — broadcast scalars, then pad rows at tau=1 (padded rows are
    # dropped after the call; tau=1 just keeps the division well-defined).
    tau_arr = jnp.broadcast_to(
        jnp.asarray(temperature, jnp.float32).reshape(-1), (batch,)
    )
    if pb:
        tau_arr = jnp.pad(tau_arr, (0, pb), constant_values=1.0)

    kernel = functools.partial(
        _stage1_kernel,
        vocab=vocab,
        tile_v=tile_v,
        want_lmass=want_lmass,
        store_logits=store_logits,
    )

    out_shapes = [
        jax.ShapeDtypeStruct((nb * tile_b, nv), jnp.float32),  # m
        jax.ShapeDtypeStruct((nb * tile_b, nv), jnp.int32),  # idx
        jax.ShapeDtypeStruct((nb * tile_b, nv), jnp.float32),  # lmass
        jax.ShapeDtypeStruct((nb * tile_b, nv * tile_v), jnp.float32),  # logits
    ]
    out_specs = [
        pl.BlockSpec((tile_b, 1), lambda bi, vi: (bi, vi)),
        pl.BlockSpec((tile_b, 1), lambda bi, vi: (bi, vi)),
        pl.BlockSpec((tile_b, 1), lambda bi, vi: (bi, vi)),
        pl.BlockSpec((tile_b, tile_v), lambda bi, vi: (bi, vi)),
    ]

    m, idx, lmass, logits = pl.pallas_call(
        kernel,
        grid=(nb, nv),
        in_specs=[
            pl.BlockSpec((tile_b, d), lambda bi, vi: (bi, 0)),  # H row tile
            pl.BlockSpec((tile_v, d), lambda bi, vi: (vi, 0)),  # W vocab tile
            pl.BlockSpec((2,), lambda bi, vi: (0,)),  # seed
            pl.BlockSpec((1,), lambda bi, vi: (0,)),  # step
            pl.BlockSpec((tile_b,), lambda bi, vi: (bi,)),  # tau row tile
            pl.BlockSpec((tile_v,), lambda bi, vi: (vi,)),  # bias tile
        ],
        out_shape=out_shapes,
        out_specs=out_specs,
        interpret=interpret,
    )(h, w, seed, step_arr, tau_arr, bias_arr)

    m = m[:batch]
    idx = idx[:batch]
    lmass = lmass[:batch] if want_lmass else None
    logits = logits[:batch, :vocab] if store_logits else None
    return m, idx, lmass, logits


def stage2_reduce(m, idx):
    """Stage 2: argmax over the small candidate buffer (Alg.1 lines 17-19)."""
    t_star = jnp.argmax(m, axis=1)
    sample = jnp.take_along_axis(idx, t_star[:, None], axis=1)[:, 0]
    best = jnp.take_along_axis(m, t_star[:, None], axis=1)[:, 0]
    return sample.astype(jnp.int32), best


def flash_sample(
    h,
    w,
    seed,
    step=0,
    temperature=1.0,
    bias=None,
    *,
    tile_b: int = DEFAULT_TILE_B,
    tile_v: int = DEFAULT_TILE_V,
    want_log_z: bool = False,
    interpret: bool = True,
) -> FlashSampleOut:
    """Exact fused sampling from Cat(softmax(transform(H @ W^T))).

    Pathwise identical to `ref.gumbel_max_sample` with the same seed/step
    (Lemma D.5): the Philox streams are indexed by global (b, i), so every
    tiling produces the same perturbed scores and hence the same argmax.
    """
    m, idx, lmass, _ = stage1_candidates(
        h,
        w,
        seed,
        step,
        temperature,
        bias,
        tile_b=tile_b,
        tile_v=tile_v,
        want_lmass=want_log_z,
        interpret=interpret,
    )
    sample, best = stage2_reduce(m, idx)
    log_z = None
    if want_log_z:
        # logsumexp over the per-tile log-masses (Appendix L).
        mx = jnp.max(lmass, axis=1, keepdims=True)
        safe = jnp.where(jnp.isfinite(mx), mx, 0.0)
        log_z = safe[:, 0] + jnp.log(jnp.sum(jnp.exp(lmass - safe), axis=1))
    return FlashSampleOut(sample=sample, max_score=best, log_z=log_z)


def flash_sample_store_logits(
    h,
    w,
    seed,
    step=0,
    temperature=1.0,
    *,
    tile_b: int = DEFAULT_TILE_B,
    tile_v: int = DEFAULT_TILE_V,
    interpret: bool = True,
):
    """Appendix K ablation: identical kernel with the logits store enabled.

    Returns (sample [B] i32, logits [B, V] f32).  Used to measure/emulate the
    extra 2B/D HBM traffic of materializing Y with no other kernel change.
    """
    m, idx, _, logits = stage1_candidates(
        h,
        w,
        seed,
        step,
        temperature,
        tile_b=tile_b,
        tile_v=tile_v,
        store_logits=True,
        interpret=interpret,
    )
    sample, _ = stage2_reduce(m, idx)
    return sample, logits


def shard_candidates(
    h,
    w_shard,
    shard_offset,
    seed,
    step=0,
    temperature=1.0,
    *,
    tile_b: int = DEFAULT_TILE_B,
    tile_v: int = DEFAULT_TILE_V,
    interpret: bool = True,
):
    """Per-rank kernel for the tensor-parallel variant (Alg. I.4 / §D.2).

    The rank holds a vocabulary shard `w_shard` covering global indices
    [shard_offset, shard_offset + V_shard).  Returns the rank-local summary
    that is fanned out to peers — O(1) scalars per row, never the shard
    logits:

      m      [B] f32 — max perturbed score within the shard
      idx    [B] i32 — *global* index attaining it
      lmass  [B] f32 — shard log-mass L_k = logsumexp(shard logits)

    Exactness: Philox positions are global (shard_offset + local i), so
    max-merging (m, idx) across ranks is pathwise identical to a single-GPU
    FlashSampling pass; alternatively the (local sample, lmass) pair supports
    the distribution-level merge of Lemma D.2 with fresh outer Gumbels.
    """
    shard_offset = jnp.asarray(shard_offset, jnp.int32).reshape(())
    vocab_shard = w_shard.shape[0]

    # Reuse the stage-1 kernel with the global index shift folded into the
    # Philox counter by offsetting i_global; implement by passing a bias of
    # zeros and shifting indices post-hoc is NOT valid (RNG must see global
    # positions), so we inline a shifted variant here.
    batch, d = h.shape
    tile_b = min(tile_b, batch)
    tile_v = min(tile_v, vocab_shard)
    nb = _ceil_div(batch, tile_b)
    nv = _ceil_div(vocab_shard, tile_v)
    pb = nb * tile_b - batch
    pv = nv * tile_v - vocab_shard
    if pb:
        h = jnp.pad(h, ((0, pb), (0, 0)))
    if pv:
        w_shard = jnp.pad(w_shard, ((0, pv), (0, 0)))

    seed = jnp.asarray(seed, jnp.uint32).reshape(2)
    step_arr = jnp.asarray(step, jnp.uint32).reshape(1)
    # tau: [B] per-row, padded like the batch rows (see stage1_candidates).
    tau_arr = jnp.broadcast_to(
        jnp.asarray(temperature, jnp.float32).reshape(-1), (batch,)
    )
    if pb:
        tau_arr = jnp.pad(tau_arr, (0, pb), constant_values=1.0)
    off_arr = jnp.asarray(shard_offset, jnp.int32).reshape(1)

    def kernel(h_ref, w_ref, seed_ref, step_ref, tau_ref, off_ref, m_ref, idx_ref, lm_ref):
        vt = pl.program_id(1)
        bt = pl.program_id(0)
        tb = h_ref.shape[0]
        tv = w_ref.shape[0]
        hh = h_ref[...].astype(jnp.float32)
        ww = w_ref[...].astype(jnp.float32)
        y = jax.lax.dot_general(
            hh, ww, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        y = y / tau_ref[...][:, None]
        i_local = (vt * tv + jnp.arange(tv, dtype=jnp.int32))[None, :]
        i_global = i_local + off_ref[0]
        b_global = (bt * tb + jnp.arange(tb, dtype=jnp.int32))[:, None]
        valid = i_local < vocab_shard
        y = jnp.where(valid, y, NEG_INF)
        g = philox.gumbel_at(
            i_global.astype(jnp.uint32),
            jnp.broadcast_to(b_global, (tb, tv)).astype(jnp.uint32),
            step_ref[0],
            seed_ref[0],
            seed_ref[1],
        )
        s = jnp.where(valid, y + g, NEG_INF)
        m_ref[...] = jnp.max(s, axis=1, keepdims=True)
        local = jnp.argmax(s, axis=1).astype(jnp.int32)
        idx_ref[...] = (i_global[0, 0] + local)[:, None]
        ymax = jnp.max(y, axis=1, keepdims=True)
        safe = jnp.where(jnp.isfinite(ymax), ymax, 0.0)
        lse = safe[:, 0] + jnp.log(jnp.sum(jnp.exp(y - safe), axis=1))
        lm_ref[...] = jnp.where(jnp.isfinite(ymax[:, 0]), lse, NEG_INF)[:, None]

    out_shapes = [
        jax.ShapeDtypeStruct((nb * tile_b, nv), jnp.float32),
        jax.ShapeDtypeStruct((nb * tile_b, nv), jnp.int32),
        jax.ShapeDtypeStruct((nb * tile_b, nv), jnp.float32),
    ]
    spec_col = pl.BlockSpec((tile_b, 1), lambda bi, vi: (bi, vi))
    m, idx, lm = pl.pallas_call(
        kernel,
        grid=(nb, nv),
        in_specs=[
            pl.BlockSpec((tile_b, d), lambda bi, vi: (bi, 0)),
            pl.BlockSpec((tile_v, d), lambda bi, vi: (vi, 0)),
            pl.BlockSpec((2,), lambda bi, vi: (0,)),
            pl.BlockSpec((1,), lambda bi, vi: (0,)),
            pl.BlockSpec((tile_b,), lambda bi, vi: (bi,)),  # tau row tile
            pl.BlockSpec((1,), lambda bi, vi: (0,)),
        ],
        out_shape=out_shapes,
        out_specs=[spec_col, spec_col, spec_col],
        interpret=interpret,
    )(h, w_shard, seed, step_arr, tau_arr, off_arr)

    m = m[:batch]
    idx = idx[:batch]
    lm = lm[:batch]
    # Reduce this rank's tiles to the per-rank summary.
    sample, best = stage2_reduce(m, idx)
    mx = jnp.max(lm, axis=1, keepdims=True)
    safe = jnp.where(jnp.isfinite(mx), mx, 0.0)
    lmass = safe[:, 0] + jnp.log(jnp.sum(jnp.exp(lm - safe), axis=1))
    return best, sample, lmass


def subvocab_candidates(
    h,
    w,
    tiles,
    seed,
    step=0,
    temperature=1.0,
    *,
    tile_b: int = DEFAULT_TILE_B,
    tile_v: int = DEFAULT_TILE_V,
    interpret: bool = True,
):
    """Tile-subset variant of the fused sampler (DESIGN.md §16).

    Runs Stage 1 only over the candidate vocab tiles listed in `tiles` — the
    per-context sub-vocabulary maintained by `rust/src/subvocab/`.  The
    certificate check (is the candidate winner provably the full-vocab
    winner?) happens on the host against per-tile weight-norm bounds; this
    kernel's job is to produce the candidate-side summary:

      sample    [B] i32 — Gumbel-argmax over the candidate tiles (global id)
      max_score [B] f32 — its perturbed score, compared against the bound
      h_norm    [B] f32 — ||h||_2 per row, the hidden-side factor of the
                           Cauchy–Schwarz bound on excluded tiles

    Args:
      tiles: [S] i32 global vocab-tile ids (tile t covers global indices
        [t*tile_v, (t+1)*tile_v)); -1 marks an unused slot.  At least one
        slot must be active per call.

    Exactness lever: Philox positions are the *global* vocab indices of the
    gathered rows, so every covered index sees exactly the perturbed score
    the full pass would compute (Lemma D.5 applies verbatim to the subset).
    The gather itself runs in XLA ahead of the kernel; on a real TPU it
    becomes scalar-prefetch-indexed tile loads (same HBM traffic: only the
    candidate tiles' W rows are ever read).
    """
    batch, d = h.shape
    vocab, d2 = w.shape
    assert d == d2, (d, d2)
    tiles = jnp.asarray(tiles, jnp.int32).reshape(-1)
    n_sel = tiles.shape[0]
    tile_b = min(tile_b, batch)
    nb = _ceil_div(batch, tile_b)

    # Gather the candidate tiles' rows into a compact [S*tile_v, D] matrix
    # plus the per-row *global* vocab index (-1 on inactive/overhang lanes).
    base = tiles[:, None] * tile_v + jnp.arange(tile_v, dtype=jnp.int32)[None, :]
    active = (tiles[:, None] >= 0) & (base < vocab)
    gidx = jnp.where(active, base, -1)  # [S, tile_v] i32
    rows = jnp.take(w, jnp.clip(gidx, 0, vocab - 1).reshape(-1), axis=0)
    gflat = gidx.reshape(-1)

    # h_norm from the unpadded rows — the bound's hidden-side factor.
    h_norm = jnp.sqrt(jnp.sum(h.astype(jnp.float32) ** 2, axis=1))

    pb = nb * tile_b - batch
    if pb:
        h = jnp.pad(h, ((0, pb), (0, 0)))
    seed = jnp.asarray(seed, jnp.uint32).reshape(2)
    step_arr = jnp.asarray(step, jnp.uint32).reshape(1)
    tau_arr = jnp.broadcast_to(
        jnp.asarray(temperature, jnp.float32).reshape(-1), (batch,)
    )
    if pb:
        tau_arr = jnp.pad(tau_arr, (0, pb), constant_values=1.0)

    def kernel(h_ref, w_ref, idx_in_ref, seed_ref, step_ref, tau_ref, m_ref, idx_ref):
        bt = pl.program_id(0)
        tb = h_ref.shape[0]
        tv = w_ref.shape[0]
        hh = h_ref[...].astype(jnp.float32)
        ww = w_ref[...].astype(jnp.float32)
        y = jax.lax.dot_general(
            hh, ww, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        y = y / tau_ref[...][:, None]
        idx = idx_in_ref[...]  # [tv] global vocab ids, -1 = inactive lane
        valid = (idx >= 0)[None, :]
        y = jnp.where(valid, y, NEG_INF)
        i_global = jnp.where(idx >= 0, idx, 0)[None, :]
        b_global = (bt * tb + jnp.arange(tb, dtype=jnp.int32))[:, None]
        g = philox.gumbel_at(
            i_global.astype(jnp.uint32),
            jnp.broadcast_to(b_global, (tb, tv)).astype(jnp.uint32),
            step_ref[0],
            seed_ref[0],
            seed_ref[1],
        )
        s = jnp.where(valid, y + g, NEG_INF)
        m_ref[...] = jnp.max(s, axis=1, keepdims=True)
        local = jnp.argmax(s, axis=1).astype(jnp.int32)
        idx_ref[...] = jnp.take(idx, local)[:, None]

    out_shapes = [
        jax.ShapeDtypeStruct((nb * tile_b, n_sel), jnp.float32),
        jax.ShapeDtypeStruct((nb * tile_b, n_sel), jnp.int32),
    ]
    spec_col = pl.BlockSpec((tile_b, 1), lambda bi, vi: (bi, vi))
    m, idx = pl.pallas_call(
        kernel,
        grid=(nb, n_sel),
        in_specs=[
            pl.BlockSpec((tile_b, d), lambda bi, vi: (bi, 0)),
            pl.BlockSpec((tile_v, d), lambda bi, vi: (vi, 0)),  # gathered tile
            pl.BlockSpec((tile_v,), lambda bi, vi: (vi,)),  # its global ids
            pl.BlockSpec((2,), lambda bi, vi: (0,)),
            pl.BlockSpec((1,), lambda bi, vi: (0,)),
            pl.BlockSpec((tile_b,), lambda bi, vi: (bi,)),  # tau row tile
        ],
        out_shape=out_shapes,
        out_specs=[spec_col, spec_col],
        interpret=interpret,
    )(h, rows, gflat, seed, step_arr, tau_arr)

    m = m[:batch]
    idx = idx[:batch]
    sample, best = stage2_reduce(m, idx)
    return sample, best, h_norm
