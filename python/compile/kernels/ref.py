"""Pure-jnp correctness oracles for FlashSampling.

Every oracle materializes the full [B, V] logits tensor — exactly what the
paper's baselines do (Algorithm A.1) and exactly what FlashSampling avoids.
The fused Pallas kernel in `flash_sampling.py` must be *pathwise* identical
to `gumbel_max_sample` (same seed => same sampled index, Lemma D.5) and
*distributionally* identical to `multinomial_sample` (chi-squared tests).
"""

from __future__ import annotations

import jax.numpy as jnp

from compile import philox


def transform_logits(y, temperature=1.0, bias=None, mask=None):
    """Deterministic logit transforms: temperature, additive bias, -inf mask.

    Matches the paper's `transform(.)` in Algorithm 1 line 3.  `mask` is a
    boolean array; False entries get probability zero (logit -> -inf).
    `temperature` is a scalar (uniform) or a [B] vector applied per row —
    the oracle side of the tau: [B] ABI.
    """
    tau = jnp.asarray(temperature, jnp.float32)
    if tau.ndim == 1:
        tau = tau[:, None]  # [B] -> broadcast over the vocab axis
    y = y.astype(jnp.float32) / tau
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if mask is not None:
        y = jnp.where(mask, y, -jnp.inf)
    return y


def logits(h, w, temperature=1.0, bias=None, mask=None):
    """Reference LM-head projection: Y = H W^T, f32 accumulation."""
    y = jnp.matmul(h.astype(jnp.float32), w.astype(jnp.float32).T)
    return transform_logits(y, temperature, bias, mask)


def gumbel_noise(batch, vocab, step, seed_lo, seed_hi):
    """[B, V] Gumbel noise at positions (b, i) — identical positions (and
    therefore identical variates) to what the fused kernel draws."""
    i = jnp.arange(vocab, dtype=jnp.uint32)[None, :]
    b = jnp.arange(batch, dtype=jnp.uint32)[:, None]
    return philox.gumbel_at(i, b, step, seed_lo, seed_hi)


def gumbel_max_sample(h, w, seed, step=0, temperature=1.0, bias=None, mask=None):
    """Monolithic Gumbel-Max over materialized logits (Algorithm I.1,
    vectorized).  The pathwise ground truth for the fused kernel."""
    y = logits(h, w, temperature, bias, mask)
    g = gumbel_noise(y.shape[0], y.shape[1], step, seed[0], seed[1])
    s = y + g
    return jnp.argmax(s, axis=1).astype(jnp.int32)


def perturbed_scores(h, w, seed, step=0, temperature=1.0, bias=None, mask=None):
    """The full [B, V] perturbed-score matrix (for tile-decomposition tests)."""
    y = logits(h, w, temperature, bias, mask)
    g = gumbel_noise(y.shape[0], y.shape[1], step, seed[0], seed[1])
    return y + g


def softmax_probs(h, w, temperature=1.0, bias=None, mask=None):
    """Exact categorical probabilities (for chi-squared goodness-of-fit)."""
    y = logits(h, w, temperature, bias, mask)
    m = jnp.max(y, axis=1, keepdims=True)
    e = jnp.exp(y - m)
    return e / jnp.sum(e, axis=1, keepdims=True)


def multinomial_sample(h, w, seed, step=0, temperature=1.0, bias=None, mask=None):
    """The paper's baseline pipeline (Algorithm A.1): materialize logits,
    softmax with the max-shift identity, prefix-sum, inverse-CDF search.
    Exact, but pays the logits round-trip + extra kernel chain.

    Uses one uniform per row from the ROW_UNIFORM Philox stream, so baseline
    and FlashSampling draws are independent (different domain separator).
    """
    y = logits(h, w, temperature, bias, mask)
    batch = y.shape[0]
    m = jnp.max(y, axis=1, keepdims=True)  # pass 1
    e = jnp.exp(y - m)
    z = jnp.sum(e, axis=1, keepdims=True)  # pass 2
    p = e / z
    c = jnp.cumsum(p, axis=1)  # prefix sum
    b = jnp.arange(batch, dtype=jnp.uint32)
    u = philox.uniform_at(jnp.uint32(0), b, step, seed[0], seed[1])
    # min{ i : c_i >= u }  — counting search per row.
    idx = jnp.sum((c < u[:, None]).astype(jnp.int32), axis=1)
    return jnp.clip(idx, 0, y.shape[1] - 1).astype(jnp.int32)


def log_z(h, w, temperature=1.0, bias=None, mask=None):
    """Row log-normalizers log sum_j exp(l_j) (Appendix L optional output)."""
    y = logits(h, w, temperature, bias, mask)
    m = jnp.max(y, axis=1)
    return m + jnp.log(jnp.sum(jnp.exp(y - m[:, None]), axis=1))


def tile_candidates(h, w, seed, step, tile_v, temperature=1.0, bias=None, mask=None):
    """Reference per-tile (max, argmax) candidates — what Stage 1 must emit.

    Returns (m [B, n_tiles] f32, idx [B, n_tiles] i32 global indices).
    """
    s = perturbed_scores(h, w, seed, step, temperature, bias, mask)
    batch, vocab = s.shape
    n_tiles = -(-vocab // tile_v)
    pad = n_tiles * tile_v - vocab
    if pad:
        s = jnp.pad(s, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    s = s.reshape(batch, n_tiles, tile_v)
    m = jnp.max(s, axis=2)
    local = jnp.argmax(s, axis=2)
    idx = local + jnp.arange(n_tiles)[None, :] * tile_v
    return m, idx.astype(jnp.int32)


def group_log_masses(h, w, group_size, temperature=1.0, bias=None, mask=None):
    """Group log-masses L_k = logsumexp over each vocabulary group (D.1)."""
    y = logits(h, w, temperature, bias, mask)
    batch, vocab = y.shape
    n_groups = -(-vocab // group_size)
    pad = n_groups * group_size - vocab
    if pad:
        y = jnp.pad(y, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    y = y.reshape(batch, n_groups, group_size)
    m = jnp.max(y, axis=2)
    safe_m = jnp.where(jnp.isfinite(m), m, 0.0)
    lse = safe_m + jnp.log(jnp.sum(jnp.exp(y - safe_m[:, :, None]), axis=2))
    return jnp.where(jnp.isfinite(m), lse, -jnp.inf)
