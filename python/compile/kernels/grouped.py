"""Group-Gumbel-Max variants (paper §D.1-D.4, Algorithms I.2-I.4).

These are the *distribution-level* exact variants: the vocabulary is
partitioned into groups (vocab tiles, streaming chunks, or tensor-parallel
shards), each group yields an exact local sample plus its log-mass
L_k = logsumexp(group logits), and a hierarchical factorization (Lemma D.2)
or a binary merge rule (Lemma D.3) recombines them into an exact sample from
the full categorical.

They are used here as reference implementations (tested by chi-squared
goodness-of-fit in python/tests/test_grouped.py) and as the specification for
the Rust implementations in rust/src/sampling/{grouped.rs,online.rs,
distributed.rs}, which run on the request path.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile import philox
from compile.kernels import ref


def _logsumexp(x, axis=None, keepdims=False):
    m = jnp.max(x, axis=axis, keepdims=True)
    safe = jnp.where(jnp.isfinite(m), m, 0.0)
    out = safe + jnp.log(jnp.sum(jnp.exp(x - safe), axis=axis, keepdims=True))
    out = jnp.where(jnp.isfinite(m), out, -jnp.inf)
    if not keepdims and axis is not None:
        out = jnp.squeeze(out, axis=axis)
    return out


def parallel_group_sample(h, w, seed, step=0, group_size=64, temperature=1.0):
    """Algorithm I.2: parallel Group-Gumbel-Max.

    Each group k computes an exact local sample z_k (within-group Gumbel-Max)
    and its log-mass L_k; an outer Gumbel-Max over {L_k} picks the winning
    group (max-stability, Lemma D.1).  Exact by Lemma D.2.

    Returns (sample [B] i32, log_z [B] f32).
    """
    y = ref.logits(h, w, temperature)
    batch, vocab = y.shape
    assert vocab % group_size == 0, "reference impl wants equal groups"
    m = vocab // group_size
    yg = y.reshape(batch, m, group_size)

    # Within-group Gumbel-Max using globally indexed noise positions.
    g = ref.gumbel_noise(batch, vocab, step, seed[0], seed[1]).reshape(
        batch, m, group_size
    )
    local = jnp.argmax(yg + g, axis=2)  # [B, m]

    # Group log-masses and the outer selection with *fresh* Gumbels
    # (STREAM_GROUP_SELECT stream, counter i = group index).
    lmass = _logsumexp(yg, axis=2)  # [B, m]
    k = jnp.arange(m, dtype=jnp.uint32)[None, :]
    b = jnp.arange(batch, dtype=jnp.uint32)[:, None]
    g_outer = -jnp.log(
        -jnp.log(
            philox.uniform_at(
                k, b, step, seed[0], seed[1], stream=philox.STREAM_GROUP_SELECT
            )
        )
    )
    k_star = jnp.argmax(lmass + g_outer, axis=1)  # [B]
    z_local = jnp.take_along_axis(local, k_star[:, None], axis=1)[:, 0]
    sample = k_star * group_size + z_local
    log_z = _logsumexp(lmass, axis=1)
    return sample.astype(jnp.int32), log_z


def online_group_sample(h, w, seed, step=0, group_size=64, temperature=1.0):
    """Algorithm I.3: streaming Group-Gumbel-Max with O(group) working memory.

    Maintains a running (log-mass, sample) pair; each new group replaces the
    running sample with probability exp(L_k - L_new) (binary merge rule,
    Lemma D.3).  The merge Bernoulli consumes the STREAM_GROUP_SELECT stream
    at counter i = group index, so the variate sequence is reproducible.

    Vectorized over the batch; the group loop is a Python loop because this is
    a reference oracle, not a performance path.
    """
    y = ref.logits(h, w, temperature)
    batch, vocab = y.shape
    assert vocab % group_size == 0
    m = vocab // group_size
    g = ref.gumbel_noise(batch, vocab, step, seed[0], seed[1])
    b = jnp.arange(batch, dtype=jnp.uint32)

    def group(k):
        yk = y[:, k * group_size : (k + 1) * group_size]
        gk = g[:, k * group_size : (k + 1) * group_size]
        zk = jnp.argmax(yk + gk, axis=1) + k * group_size
        lk = _logsumexp(yk, axis=1)
        return zk, lk

    z, lrun = group(0)
    for k in range(1, m):
        zk, lk = group(k)
        lnew = jnp.logaddexp(lrun, lk)
        p_replace = jnp.exp(lk - lnew)
        u = philox.uniform_at(
            jnp.uint32(k), b, step, seed[0], seed[1],
            stream=philox.STREAM_GROUP_SELECT,
        )
        z = jnp.where(u < p_replace, zk, z)
        lrun = lnew
    return z.astype(jnp.int32), lrun


def distributed_sample(shard_summaries, seed, step=0):
    """Algorithm I.4 merge: exact sample over tensor-parallel shards.

    Args:
      shard_summaries: list over ranks of (local_sample [B] i32 *global*
        indices, lmass [B] f32) as produced by
        flash_sampling.shard_candidates (drop the pathwise max entry).
      seed, step: RNG position for the outer rank selection (fresh Gumbels on
        STREAM_GROUP_SELECT with counter i = rank).

    Returns (sample [B] i32, log_z [B] f32).  Exact by Theorem D.4: the
    communication is O(1) scalars per rank per row.
    """
    locals_ = jnp.stack([s for s, _ in shard_summaries], axis=1)  # [B, n]
    lmass = jnp.stack([l for _, l in shard_summaries], axis=1)  # [B, n]
    batch, n = lmass.shape
    k = jnp.arange(n, dtype=jnp.uint32)[None, :]
    b = jnp.arange(batch, dtype=jnp.uint32)[:, None]
    g_outer = -jnp.log(
        -jnp.log(
            philox.uniform_at(
                k, b, step, seed[0], seed[1], stream=philox.STREAM_GROUP_SELECT
            )
        )
    )
    k_star = jnp.argmax(lmass + g_outer, axis=1)
    sample = jnp.take_along_axis(locals_, k_star[:, None], axis=1)[:, 0]
    log_z = _logsumexp(lmass, axis=1)
    return sample.astype(jnp.int32), log_z


def distributed_sample_pathwise(shard_maxima):
    """Pathwise tensor-parallel merge (paper §3.2 multi-GPU path).

    Because every rank's Gumbel stream is indexed by *global* (b, i), the
    rank-local (max perturbed score, argmax) summaries max-merge to exactly
    the single-device FlashSampling result (Lemma D.5 applied to the shard
    partition).  This is the P2P fan-out payload in Algorithm 1 lines 10-12.

    Args:
      shard_maxima: list over ranks of (m [B] f32, idx [B] i32 global).
    Returns sample [B] i32, identical to single-rank flash_sample.
    """
    m = jnp.stack([mm for mm, _ in shard_maxima], axis=1)
    idx = jnp.stack([ii for _, ii in shard_maxima], axis=1)
    r_star = jnp.argmax(m, axis=1)
    return jnp.take_along_axis(idx, r_star[:, None], axis=1)[:, 0].astype(jnp.int32)
