"""AOT lowering: JAX/Pallas -> HLO text artifacts + manifest + weights.

Interchange format is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the `xla` crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs under artifacts/:
  manifest.json            — artifact + weight registry (the Rust runtime's
                             source of truth; schema documented below)
  <name>.hlo.txt           — one XLA computation per (function, shape) pair
  weights/<param>.bin      — raw little-endian f32 tensors, canonical order

Manifest schema:
  {
    "version": ABI version int (3 = tau:[B] + sub-vocab; see TAU_ABI_VERSION),
    "model": {"vocab":…, "d_model":…, "n_layers":…, "n_heads":…, "ffn":…,
              "max_seq":…, "param_order": [names…]},
    "artifacts": [
      {"name": str, "file": str, "kind": str,
       "inputs":  [{"name": str, "shape": [ints], "dtype": "f32"|"i32"|"u32"}],
       "outputs": [{"name": str, "shape": [ints], "dtype": …}],
       "meta": {free-form ints/floats: B, D, V, tile_v, shard, n_shards, …}},
      …],
    "weights": [{"name": str, "file": str, "shape": [ints], "dtype": "f32"}]
  }

Python runs once at build time (`make artifacts`); nothing here is imported
on the request path.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as model_lib
from compile.kernels import flash_sampling as fs
from compile.kernels import ref as kref

# ---------------------------------------------------------------------------
# Shape catalogue — the fixed-shape executables the coordinator can launch.
# ---------------------------------------------------------------------------

SERVE_CFG = model_lib.ModelConfig()

# Artifact ABI version, mirrored by rust/src/runtime/manifest.rs
# (TAU_ABI_VERSION).  v2: every sampling artifact takes `tau` as a [B]
# per-row temperature vector instead of a scalar — the change that lets the
# scheduler coalesce mixed-temperature requests into one batch.  v3 adds the
# `decode_sample_sub_b{B}` candidate-tile artifacts (DESIGN.md §16): a
# `tiles: [SUB_TILES]` i32 input plus (winner score, hidden norm) outputs —
# the runtime inputs of the sub-vocabulary exactness certificate.
TAU_ABI_VERSION = 3

# Decode batch buckets: the continuous batcher pads the running batch up to
# the nearest bucket (vLLM uses CUDA-graph capture sizes the same way).
DECODE_BUCKETS = (1, 2, 4, 8)

# Certified sub-vocabulary decode (ABI v3, DESIGN.md §16): the candidate
# artifact takes a fixed-width tile-id list; unused slots are -1.  The vocab
# is partitioned into SUB_TILE_V-wide tiles for candidate ranking — finer
# than DEFAULT_TILE_V so a small budget still covers the hot head of the
# unigram distribution (2048-vocab serving model -> 16 rankable tiles).
# Mirrored by rust/src/subvocab/ (SUB_TILE_V, SUB_TILE_SLOTS).
SUB_TILES = 4
SUB_TILE_V = 128
PREFILL_T_BUCKETS = (16, 64)
PREFILL_B = 4  # prefill executes fixed [PREFILL_B, T] prompt batches

# Standalone LM-head sampling kernels at benchmark shapes (Rust microbench
# uses these to compare fused vs baseline end-to-end through PJRT).
BENCH_SHAPES = (
    # (B, D, V, tile_v)
    (1, 256, 2048, 512),
    (4, 256, 2048, 512),
    (16, 256, 2048, 512),
    (4, 512, 8192, 1024),
    (16, 512, 8192, 1024),
)

# Tensor-parallel shard kernels (vocab sharding) for the tp runtime.
# One shape per decode bucket: the engine's TP decode seam
# (EngineConfig::tp) fans every decode batch out through the orchestrator,
# so each bucket's batch size needs its shard executables.
TP_DEGREES = (2, 4)
TP_SHAPES = (
    (1, 256, 2048, 512),
    (2, 256, 2048, 512),
    (4, 256, 2048, 512),
    (8, 256, 2048, 512),
)


def _dt(x) -> str:
    return {np.dtype(np.float32): "f32", np.dtype(np.int32): "i32",
            np.dtype(np.uint32): "u32"}[np.dtype(x)]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


class Builder:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.artifacts = []
        os.makedirs(out_dir, exist_ok=True)
        os.makedirs(os.path.join(out_dir, "weights"), exist_ok=True)

    def add(self, name: str, kind: str, fn, specs: Sequence[jax.ShapeDtypeStruct],
            input_names: Sequence[str], meta: dict):
        """Lower `fn` at `specs`, write HLO text, record manifest entry.

        keep_unused=True: the Rust runtime passes every input positionally
        (the manifest ABI), so XLA must not prune parameters a particular
        graph doesn't read (e.g. prefill never touches lm_head).
        """
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_tree = jax.eval_shape(fn, *specs)
        outputs = []
        for i, leaf in enumerate(jax.tree_util.tree_leaves(out_tree)):
            outputs.append(
                {"name": f"out{i}", "shape": list(leaf.shape), "dtype": _dt(leaf.dtype)}
            )
        self.artifacts.append(
            {
                "name": name,
                "file": fname,
                "kind": kind,
                "inputs": [
                    {"name": n, "shape": list(s.shape), "dtype": _dt(s.dtype)}
                    for n, s in zip(input_names, specs)
                ],
                "outputs": outputs,
                "meta": meta,
            }
        )
        print(f"  [aot] {name}: {len(text)} chars, {len(specs)} inputs")


def export_weights(builder: Builder, cfg: model_lib.ModelConfig, seed: int):
    params = model_lib.init_params(cfg, seed)
    entries = []
    for name in cfg.param_order():
        arr = np.asarray(params[name], np.float32)
        fname = os.path.join("weights", name.replace("/", "_") + ".bin")
        arr.tofile(os.path.join(builder.out_dir, fname))
        entries.append(
            {"name": name, "file": fname, "shape": list(arr.shape), "dtype": "f32"}
        )
    return params, entries


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def u32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint32)


def build_sampler_artifacts(b: Builder):
    """Standalone LM-head+sampling kernels at benchmark shapes."""
    for (bsz, d, v, tile_v) in BENCH_SHAPES:
        tag = f"b{bsz}_d{d}_v{v}"
        meta = {"B": bsz, "D": d, "V": v, "tile_v": tile_v}

        # tau is a [B] per-row vector everywhere (ABI v2).
        def fused(h, w, seed, step, tau, _tile_v=tile_v):
            out = fs.flash_sample(h, w, seed, step[0], tau, tile_v=_tile_v)
            return out.sample

        def fused_logz(h, w, seed, step, tau, _tile_v=tile_v):
            out = fs.flash_sample(
                h, w, seed, step[0], tau, tile_v=_tile_v, want_log_z=True
            )
            return out.sample, out.log_z

        def baseline(h, w, seed, step, tau):
            return kref.multinomial_sample(h, w, seed, step[0], tau)

        def gumbel_ref(h, w, seed, step, tau):
            # FI2-style: materialized logits + Gumbel-Max (no fusion).
            return kref.gumbel_max_sample(h, w, seed, step[0], tau)

        def store_logits(h, w, seed, step, tau, _tile_v=tile_v):
            s, logits = fs.flash_sample_store_logits(
                h, w, seed, step[0], tau, tile_v=_tile_v
            )
            return s, logits

        specs = [f32(bsz, d), f32(v, d), u32(2), u32(1), f32(bsz)]
        names = ["h", "w", "seed", "step", "tau"]
        b.add(f"flash_sample_{tag}", "flash_sample", fused, specs, names, meta)
        b.add(f"flash_sample_logz_{tag}", "flash_sample_logz", fused_logz, specs,
              names, meta)
        b.add(f"baseline_multinomial_{tag}", "baseline_multinomial", baseline,
              specs, names, meta)
        b.add(f"baseline_gumbel_{tag}", "baseline_gumbel", gumbel_ref, specs,
              names, meta)
        if bsz <= 4:  # ablation artifact only at small B (logits output is big)
            b.add(f"flash_sample_store_{tag}", "flash_sample_store", store_logits,
                  specs, names, {**meta, "ablation": "logits_store"})


def build_tp_artifacts(b: Builder):
    """Per-rank vocab-shard kernels (Alg. I.4).  One artifact per TP degree;
    the shard offset is a runtime input so all ranks share the executable."""
    for (bsz, d, v, tile_v) in TP_SHAPES:
        for n in TP_DEGREES:
            vs = v // n
            tag = f"b{bsz}_d{d}_v{v}_tp{n}"

            def shard(h, w_shard, off, seed, step, tau, _tile_v=tile_v):
                m, local, lmass = fs.shard_candidates(
                    h, w_shard, off[0], seed, step[0], tau, tile_v=_tile_v
                )
                return m, local, lmass

            b.add(
                f"shard_sample_{tag}",
                "shard_sample",
                shard,
                [f32(bsz, d), f32(vs, d), i32(1), u32(2), u32(1), f32(bsz)],
                ["h", "w_shard", "shard_offset", "seed", "step", "tau"],
                {"B": bsz, "D": d, "V": v, "V_shard": vs, "n_shards": n,
                 "tile_v": tile_v},
            )

            def shard_logits(h, w_shard):
                # The all-gather baseline's per-rank payload: the FULL local
                # logits shard [B, V/n] (what FlashSampling's O(1) summaries
                # replace).  Materialized deliberately.
                return (jnp.matmul(h, w_shard.T),)

            b.add(
                f"shard_logits_{tag}",
                "shard_logits",
                shard_logits,
                [f32(bsz, d), f32(vs, d)],
                ["h", "w_shard"],
                {"B": bsz, "D": d, "V": v, "V_shard": vs, "n_shards": n},
            )


def build_model_artifacts(b: Builder, cfg: model_lib.ModelConfig):
    """The serving model: prefill, fused decode+sample, baseline decode."""
    n_params = len(cfg.param_order())
    shapes = cfg.param_shapes()
    param_specs = [f32(*shapes[n]) for n in cfg.param_order()]
    kv = f32(cfg.n_layers, 0, cfg.n_heads, cfg.max_seq, cfg.head_dim)  # B patched

    def kv_spec(bsz):
        return f32(cfg.n_layers, bsz, cfg.n_heads, cfg.max_seq, cfg.head_dim)

    for bsz in DECODE_BUCKETS:
        meta = {"B": bsz, "D": cfg.d_model, "V": cfg.vocab}

        def fused(*args, _b=bsz):
            params = dict(zip(cfg.param_order(), args[:n_params]))
            kv_k, kv_v, pos, token, seed, step, tau = args[n_params:]
            return model_lib.decode_and_sample(
                cfg, params, kv_k, kv_v, pos, token, seed, step[0], tau
            )

        def baseline(*args, _b=bsz):
            params = dict(zip(cfg.param_order(), args[:n_params]))
            kv_k, kv_v, pos, token, seed, step, tau = args[n_params:]
            return model_lib.decode_and_sample_baseline(
                cfg, params, kv_k, kv_v, pos, token, seed, step[0], tau
            )

        specs = param_specs + [
            kv_spec(bsz), kv_spec(bsz), i32(bsz), i32(bsz), u32(2), u32(1),
            f32(bsz)
        ]
        names = list(cfg.param_order()) + [
            "kv_k", "kv_v", "pos", "token", "seed", "step", "tau"
        ]
        b.add(f"decode_sample_b{bsz}", "decode_sample", fused, specs, names, meta)
        b.add(f"decode_baseline_b{bsz}", "decode_baseline", baseline, specs,
              names, meta)

        # Certified sub-vocabulary decode (DESIGN.md §16): LM head over the
        # candidate tiles only.  One extra input — `tiles` [SUB_TILES] i32
        # global vocab-tile ids (-1 = unused slot) — and two extra outputs:
        # the candidate winner's perturbed score and ||h|| per row, which
        # the Rust engine feeds into the host-side exactness certificate
        # before accepting the skipped-tile sample.
        def fused_sub(*args, _b=bsz):
            params = dict(zip(cfg.param_order(), args[:n_params]))
            kv_k, kv_v, pos, token, seed, step, tau, tiles = args[n_params:]
            return model_lib.decode_and_sample_sub(
                cfg, params, kv_k, kv_v, pos, token, seed, step[0], tau,
                tiles, tile_v=SUB_TILE_V,
            )

        b.add(
            f"decode_sample_sub_b{bsz}",
            "decode_sample_sub",
            fused_sub,
            specs + [i32(SUB_TILES)],
            names + ["tiles"],
            {**meta, "sub_tiles": SUB_TILES, "sub_tile_v": SUB_TILE_V},
        )

        # TP decode seam (DESIGN.md §13): the transformer step WITHOUT the
        # sampling epilogue — returns the final hidden states so the TP
        # orchestrator can fan the LM head out across vocab shards.  No
        # seed/step/tau inputs: sampling happens rank-side with the same
        # Philox (row, counter-step) coordinates the fused artifact uses,
        # which is what keeps shard count out of the token stream.
        def hidden_only(*args, _b=bsz):
            params = dict(zip(cfg.param_order(), args[:n_params]))
            kv_k, kv_v, pos, token = args[n_params:]
            return model_lib.decode_step(cfg, params, kv_k, kv_v, pos, token)

        b.add(
            f"decode_hidden_b{bsz}",
            "decode_hidden",
            hidden_only,
            param_specs + [kv_spec(bsz), kv_spec(bsz), i32(bsz), i32(bsz)],
            list(cfg.param_order()) + ["kv_k", "kv_v", "pos", "token"],
            meta,
        )

    for t in PREFILL_T_BUCKETS:
        def pre(*args, _t=t):
            params = dict(zip(cfg.param_order(), args[:n_params]))
            tokens, lengths = args[n_params:]
            return model_lib.prefill(cfg, params, tokens, lengths)

        b.add(
            f"prefill_b{PREFILL_B}_t{t}",
            "prefill",
            pre,
            param_specs + [i32(PREFILL_B, t), i32(PREFILL_B)],
            list(cfg.param_order()) + ["tokens", "lengths"],
            {"B": PREFILL_B, "T": t, "D": cfg.d_model, "V": cfg.vocab},
        )

        # Prefix-cached suffix prefill (DESIGN.md §10): positions offset
        # per row, attention over restored cached KV + in-suffix causal.
        # Bitwise-identical to full prefill on XLA CPU
        # (python/tests/test_prefix_cache.py), so the engine's prefix
        # caching is exact, not approximate.
        def pre_cached(*args, _t=t):
            params = dict(zip(cfg.param_order(), args[:n_params]))
            kv_k, kv_v, offset, tokens, lengths = args[n_params:]
            return model_lib.prefill_cached(
                cfg, params, kv_k, kv_v, offset, tokens, lengths
            )

        b.add(
            f"prefill_cached_b{PREFILL_B}_t{t}",
            "prefill_cached",
            pre_cached,
            param_specs
            + [kv_spec(PREFILL_B), kv_spec(PREFILL_B), i32(PREFILL_B),
               i32(PREFILL_B, t), i32(PREFILL_B)],
            list(cfg.param_order())
            + ["kv_k", "kv_v", "offset", "tokens", "lengths"],
            {"B": PREFILL_B, "T": t, "D": cfg.d_model, "V": cfg.vocab},
        )

    # First-token sampler (hidden -> token) shared across prefill buckets.
    # tau: [B] — each prompt's own temperature (the prefill first-token
    # bug fix rides on this).
    def first_token(hidden, lm_head, seed, step, tau):
        return fs.flash_sample(hidden, lm_head, seed, step[0], tau).sample

    b.add(
        f"sample_hidden_b{PREFILL_B}",
        "sample_hidden",
        first_token,
        [f32(PREFILL_B, cfg.d_model), f32(cfg.vocab, cfg.d_model), u32(2),
         u32(1), f32(PREFILL_B)],
        ["hidden", "lm_head", "seed", "step", "tau"],
        {"B": PREFILL_B, "D": cfg.d_model, "V": cfg.vocab},
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output dir")
    ap.add_argument("--seed", type=int, default=0, help="weight init seed")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: samplers,tp,model")
    args = ap.parse_args()

    only = set(args.only.split(",")) if args.only else {"samplers", "tp", "model"}
    b = Builder(args.out)
    print(f"[aot] building artifacts in {args.out} (sections: {sorted(only)})")

    _, weight_entries = export_weights(b, SERVE_CFG, args.seed)
    if "samplers" in only:
        build_sampler_artifacts(b)
    if "tp" in only:
        build_tp_artifacts(b)
    if "model" in only:
        build_model_artifacts(b, SERVE_CFG)

    # --only partial builds merge into the existing manifest (keyed by
    # artifact name) so a subset rebuild never drops other entries.
    merged = {a["name"]: a for a in []}
    manifest_path = os.path.join(args.out, "manifest.json")
    if args.only and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
        merged = {a["name"]: a for a in old.get("artifacts", [])}
    for a in b.artifacts:
        merged[a["name"]] = a
    all_artifacts = sorted(merged.values(), key=lambda a: a["name"])

    manifest = {
        "version": TAU_ABI_VERSION,
        "model": {
            "vocab": SERVE_CFG.vocab,
            "d_model": SERVE_CFG.d_model,
            "n_layers": SERVE_CFG.n_layers,
            "n_heads": SERVE_CFG.n_heads,
            "ffn": SERVE_CFG.ffn,
            "max_seq": SERVE_CFG.max_seq,
            "param_order": SERVE_CFG.param_order(),
            "decode_buckets": list(DECODE_BUCKETS),
            "prefill_t_buckets": list(PREFILL_T_BUCKETS),
            "prefill_b": PREFILL_B,
            "weight_seed": args.seed,
        },
        "artifacts": all_artifacts,
        "weights": weight_entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest with {len(all_artifacts)} artifacts, "
          f"{len(weight_entries)} weight tensors")


if __name__ == "__main__":
    main()
