"""Fused Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

Pathwise exactness (Lemma D.5): with the same seed/step, the fused tiled
kernel must return *bit-identical* samples to a monolithic Gumbel-Max over
materialized logits, for every tiling, dtype, transform, and padding case.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_sampling as fs
from compile.kernels import ref

SEED = (0xDEADBEEF, 0x12345678)


def mk(b, d, v, dtype=jnp.float32, scale=0.3, key=0):
    kh, kw = jax.random.split(jax.random.PRNGKey(key))
    h = jax.random.normal(kh, (b, d), jnp.float32).astype(dtype)
    w = (jax.random.normal(kw, (v, d), jnp.float32) * scale).astype(dtype)
    return h, w


class TestPathwiseExactness:
    @pytest.mark.parametrize("tile_b,tile_v", [(1, 64), (2, 128), (8, 512),
                                               (3, 100), (5, 1000)])
    def test_matches_reference_all_tilings(self, tile_b, tile_v):
        h, w = mk(5, 64, 1000)
        expect = np.asarray(ref.gumbel_max_sample(h, w, SEED, step=7))
        got = np.asarray(
            fs.flash_sample(h, w, SEED, step=7, tile_b=tile_b, tile_v=tile_v).sample
        )
        np.testing.assert_array_equal(got, expect)

    def test_tilings_agree_with_each_other(self):
        h, w = mk(4, 32, 777)
        outs = [
            np.asarray(fs.flash_sample(h, w, SEED, tile_b=tb, tile_v=tv).sample)
            for tb, tv in [(1, 32), (4, 777), (2, 256), (4, 64)]
        ]
        for o in outs[1:]:
            np.testing.assert_array_equal(o, outs[0])

    def test_step_varies_noise(self):
        h, w = mk(8, 64, 2048)
        s0 = np.asarray(fs.flash_sample(h, w, SEED, step=0).sample)
        s1 = np.asarray(fs.flash_sample(h, w, SEED, step=1).sample)
        assert (s0 != s1).any()  # fresh noise per decode step
        np.testing.assert_array_equal(
            s1, np.asarray(ref.gumbel_max_sample(h, w, SEED, step=1))
        )

    def test_seed_varies_noise(self):
        h, w = mk(8, 64, 2048)
        s0 = np.asarray(fs.flash_sample(h, w, SEED).sample)
        s1 = np.asarray(fs.flash_sample(h, w, (1, 2)).sample)
        assert (s0 != s1).any()

    def test_bf16_inputs_f32_accumulation(self):
        h, w = mk(4, 64, 512, dtype=jnp.bfloat16)
        expect = np.asarray(ref.gumbel_max_sample(h, w, SEED))
        got = np.asarray(fs.flash_sample(h, w, SEED, tile_v=128).sample)
        np.testing.assert_array_equal(got, expect)

    def test_batch_one(self):
        h, w = mk(1, 64, 512)
        expect = np.asarray(ref.gumbel_max_sample(h, w, SEED))
        got = np.asarray(fs.flash_sample(h, w, SEED, tile_b=8, tile_v=128).sample)
        np.testing.assert_array_equal(got, expect)

    def test_vocab_not_tile_multiple(self):
        # 1000 = 7*128 + 104: padding lanes must never win.
        h, w = mk(4, 32, 1000)
        expect = np.asarray(ref.gumbel_max_sample(h, w, SEED))
        got = np.asarray(fs.flash_sample(h, w, SEED, tile_v=128).sample)
        np.testing.assert_array_equal(got, expect)


class TestTransforms:
    def test_temperature(self):
        h, w = mk(4, 64, 512)
        for tau in (0.25, 0.7, 1.0, 2.5):
            expect = np.asarray(ref.gumbel_max_sample(h, w, SEED, temperature=tau))
            got = np.asarray(
                fs.flash_sample(h, w, SEED, temperature=tau, tile_v=128).sample
            )
            np.testing.assert_array_equal(got, expect)

    def test_low_temperature_approaches_greedy(self):
        h, w = mk(4, 64, 512, key=3)
        greedy = np.asarray(jnp.argmax(ref.logits(h, w), axis=1))
        got = np.asarray(
            fs.flash_sample(h, w, SEED, temperature=1e-4, tile_v=128).sample
        )
        np.testing.assert_array_equal(got, greedy)

    def test_additive_bias(self):
        h, w = mk(4, 64, 512)
        bias = jax.random.normal(jax.random.PRNGKey(9), (512,)) * 2.0
        expect = np.asarray(ref.gumbel_max_sample(h, w, SEED, bias=bias))
        got = np.asarray(fs.flash_sample(h, w, SEED, bias=bias, tile_v=128).sample)
        np.testing.assert_array_equal(got, expect)

    def test_neg_inf_mask_restricts_support(self):
        # Ban everything outside [100, 200) via the bias path (-inf mask).
        h, w = mk(8, 64, 512)
        bias = jnp.full((512,), -jnp.inf).at[100:200].set(0.0)
        got = np.asarray(fs.flash_sample(h, w, SEED, bias=bias, tile_v=64).sample)
        assert ((got >= 100) & (got < 200)).all()
        expect = np.asarray(ref.gumbel_max_sample(h, w, SEED, bias=bias))
        np.testing.assert_array_equal(got, expect)


class TestOutputs:
    def test_log_z_matches_reference(self):
        h, w = mk(4, 64, 1000)
        out = fs.flash_sample(h, w, SEED, tile_v=128, want_log_z=True)
        np.testing.assert_allclose(
            np.asarray(out.log_z), np.asarray(ref.log_z(h, w)), rtol=1e-5
        )

    def test_max_score_matches_reference(self):
        h, w = mk(4, 64, 1000)
        out = fs.flash_sample(h, w, SEED, tile_v=128)
        s = np.asarray(ref.perturbed_scores(h, w, SEED))
        np.testing.assert_allclose(
            np.asarray(out.max_score), s.max(axis=1), rtol=1e-6
        )

    def test_store_logits_ablation_matches_reference_logits(self):
        h, w = mk(4, 64, 1000)
        sample, logits = fs.flash_sample_store_logits(h, w, SEED, tile_v=128)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref.logits(h, w)), rtol=1e-5, atol=1e-5
        )
        # and the sample is unchanged by the store flag
        np.testing.assert_array_equal(
            np.asarray(sample),
            np.asarray(fs.flash_sample(h, w, SEED, tile_v=128).sample),
        )

    def test_stage1_candidates_match_reference_tiles(self):
        h, w = mk(3, 32, 640)
        m, idx, _, _ = fs.stage1_candidates(h, w, SEED, tile_b=3, tile_v=128)
        rm, ridx = ref.tile_candidates(h, w, SEED, 0, 128)
        np.testing.assert_allclose(np.asarray(m), np.asarray(rm), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))


class TestShardKernel:
    def test_shard_merge_is_pathwise_exact(self):
        h, w = mk(6, 64, 1024)
        expect = np.asarray(ref.gumbel_max_sample(h, w, SEED, step=2))
        n = 4
        vs = 1024 // n
        best = []
        for r in range(n):
            m, s, _ = fs.shard_candidates(
                h, w[r * vs : (r + 1) * vs], r * vs, SEED, step=2, tile_v=128
            )
            best.append((np.asarray(m), np.asarray(s)))
        m = np.stack([b[0] for b in best], axis=1)
        idx = np.stack([b[1] for b in best], axis=1)
        got = idx[np.arange(6), m.argmax(axis=1)]
        np.testing.assert_array_equal(got, expect)

    def test_shard_lmass_sums_to_log_z(self):
        h, w = mk(4, 64, 1024)
        n = 2
        vs = 1024 // n
        lm = []
        for r in range(n):
            _, _, lmass = fs.shard_candidates(
                h, w[r * vs : (r + 1) * vs], r * vs, SEED, tile_v=256
            )
            lm.append(np.asarray(lmass))
        total = np.logaddexp(lm[0], lm[1])
        np.testing.assert_allclose(total, np.asarray(ref.log_z(h, w)), rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 9),
    d=st.sampled_from([16, 48, 64]),
    v=st.integers(33, 700),
    tile_v=st.sampled_from([32, 100, 256]),
    tile_b=st.sampled_from([1, 2, 4, 8]),
    step=st.integers(0, 2**31 - 1),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_hypothesis_pathwise_sweep(b, d, v, tile_v, tile_b, step, dtype):
    """Property: for ANY shape/tiling/dtype/step, fused == monolithic."""
    h, w = mk(b, d, v, dtype=dtype, key=b * 1000 + v)
    expect = np.asarray(ref.gumbel_max_sample(h, w, SEED, step=step))
    got = np.asarray(
        fs.flash_sample(h, w, SEED, step=step, tile_b=tile_b, tile_v=tile_v).sample
    )
    np.testing.assert_array_equal(got, expect)


@settings(max_examples=10, deadline=None)
@given(
    v=st.integers(64, 500),
    n_banned=st.integers(0, 60),
    tau=st.floats(0.3, 3.0),
)
def test_hypothesis_mask_and_temperature(v, n_banned, tau):
    """Property: banned tokens never sampled; transform matches oracle."""
    h, w = mk(4, 32, v, key=v)
    rng = np.random.RandomState(v)
    banned = rng.choice(v, size=min(n_banned, v - 1), replace=False)
    bias = np.zeros(v, np.float32)
    bias[banned] = -np.inf
    bias = jnp.asarray(bias)
    got = np.asarray(
        fs.flash_sample(h, w, SEED, temperature=tau, bias=bias, tile_v=96).sample
    )
    assert not np.isin(got, banned).any()
    expect = np.asarray(
        ref.gumbel_max_sample(h, w, SEED, temperature=tau, bias=bias)
    )
    np.testing.assert_array_equal(got, expect)
