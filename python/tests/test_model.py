"""L2 model tests: shapes, prefill/decode consistency, fused sampling path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

CFG = M.ModelConfig(vocab=128, d_model=32, n_layers=2, n_heads=2, ffn=64,
                    max_seq=32)
SEED = (10, 20)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def _empty_kv(b):
    shape = (CFG.n_layers, b, CFG.n_heads, CFG.max_seq, CFG.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


class TestShapes:
    def test_param_shapes_cover_order(self):
        shapes = CFG.param_shapes()
        assert set(CFG.param_order()) == set(shapes)
        assert CFG.param_order() == sorted(CFG.param_order())

    def test_decode_step_shapes(self, params):
        b = 3
        kv_k, kv_v = _empty_kv(b)
        tok = jnp.array([1, 2, 3], jnp.int32)
        pos = jnp.zeros(b, jnp.int32)
        nk, nv, hidden = M.decode_step(CFG, params, kv_k, kv_v, pos, tok)
        assert nk.shape == kv_k.shape and nv.shape == kv_v.shape
        assert hidden.shape == (b, CFG.d_model)

    def test_prefill_shapes(self, params):
        b, t = 2, 8
        toks = jnp.ones((b, t), jnp.int32)
        lens = jnp.array([5, 8], jnp.int32)
        kv_k, kv_v, h = M.prefill(CFG, params, toks, lens)
        assert kv_k.shape == (CFG.n_layers, b, CFG.n_heads, CFG.max_seq,
                              CFG.head_dim)
        assert h.shape == (b, CFG.d_model)


class TestPrefillDecodeConsistency:
    def test_decode_continues_prefill(self, params):
        """Hidden state from (prefill T tokens, then decode token T) must
        match (prefill T+1 tokens) — the cache handoff is seamless."""
        toks = jnp.array([[3, 14, 15, 9, 2, 6]], jnp.int32)
        t = toks.shape[1]
        kv_k, kv_v, _ = M.prefill(CFG, params, toks[:, : t - 1],
                                  jnp.array([t - 1], jnp.int32))
        _, _, h_dec = M.decode_step(
            CFG, params, kv_k, kv_v, jnp.array([t - 1], jnp.int32), toks[:, -1]
        )
        _, _, h_full = M.prefill(CFG, params, toks, jnp.array([t], jnp.int32))
        np.testing.assert_allclose(
            np.asarray(h_dec), np.asarray(h_full), rtol=2e-4, atol=2e-5
        )

    def test_padded_prefill_matches_exact_prefill(self, params):
        """Rows padded beyond their length must produce the same last-token
        hidden as an unpadded run (padding is fully masked)."""
        toks = jnp.array([[5, 6, 7, 0, 0, 0, 0, 0]], jnp.int32)
        _, _, h_pad = M.prefill(CFG, params, toks, jnp.array([3], jnp.int32))
        _, _, h_exact = M.prefill(CFG, params, toks[:, :3],
                                  jnp.array([3], jnp.int32))
        np.testing.assert_allclose(
            np.asarray(h_pad), np.asarray(h_exact), rtol=2e-4, atol=2e-5
        )

    def test_multistep_decode_matches_prefill(self, params):
        toks = jnp.array([[1, 2, 3, 4, 5]], jnp.int32)
        kv_k, kv_v, _ = M.prefill(CFG, params, toks[:, :2],
                                  jnp.array([2], jnp.int32))
        for i in range(2, 5):
            kv_k, kv_v, h = M.decode_step(
                CFG, params, kv_k, kv_v, jnp.array([i], jnp.int32), toks[:, i]
            )
        _, _, h_full = M.prefill(CFG, params, toks, jnp.array([5], jnp.int32))
        np.testing.assert_allclose(
            np.asarray(h), np.asarray(h_full), rtol=2e-4, atol=2e-5
        )


class TestFusedServingPath:
    def test_decode_and_sample_matches_oracle(self, params):
        """The fused decode+sample artifact must equal: decode_step hidden ->
        monolithic Gumbel-Max (pathwise, Lemma D.5 through the whole graph)."""
        b = 2
        kv_k, kv_v = _empty_kv(b)
        tok = jnp.array([7, 9], jnp.int32)
        pos = jnp.zeros(b, jnp.int32)
        nk, nv, sample = M.decode_and_sample(
            CFG, params, kv_k, kv_v, pos, tok, SEED, step=4, temperature=1.0
        )
        _, _, hidden = M.decode_step(CFG, params, kv_k, kv_v, pos, tok)
        expect = ref.gumbel_max_sample(hidden, params["lm_head"], SEED, step=4)
        np.testing.assert_array_equal(np.asarray(sample), np.asarray(expect))

    def test_baseline_artifact_samples_valid_tokens(self, params):
        b = 2
        kv_k, kv_v = _empty_kv(b)
        tok = jnp.array([1, 2], jnp.int32)
        pos = jnp.zeros(b, jnp.int32)
        _, _, sample = M.decode_and_sample_baseline(
            CFG, params, kv_k, kv_v, pos, tok, SEED, step=0, temperature=1.0
        )
        s = np.asarray(sample)
        assert ((s >= 0) & (s < CFG.vocab)).all()

    def test_sample_from_hidden_matches_flash(self, params):
        h = jax.random.normal(jax.random.PRNGKey(2), (4, CFG.d_model))
        s = M.sample_from_hidden(CFG, params, h, SEED, step=1, temperature=0.8)
        expect = ref.gumbel_max_sample(
            h, params["lm_head"], SEED, step=1, temperature=0.8
        )
        np.testing.assert_array_equal(np.asarray(s), np.asarray(expect))

    def test_deterministic_given_seed(self, params):
        b = 2
        kv_k, kv_v = _empty_kv(b)
        tok = jnp.array([5, 6], jnp.int32)
        pos = jnp.zeros(b, jnp.int32)
        s1 = M.decode_and_sample(CFG, params, kv_k, kv_v, pos, tok, SEED, 0, 1.0)[2]
        s2 = M.decode_and_sample(CFG, params, kv_k, kv_v, pos, tok, SEED, 0, 1.0)[2]
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


class TestNumerics:
    def test_rmsnorm_unit_scale(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 32)) * 7.0
        y = np.asarray(M.rmsnorm(x, jnp.ones(32)))
        np.testing.assert_allclose((y ** 2).mean(axis=-1), 1.0, rtol=1e-3)

    def test_rope_preserves_norm(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 4, 16))
        pos = jnp.arange(3)[None, :] * jnp.ones((2, 1), jnp.int32)
        y = M.rope(x, pos, 10000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(y), axis=-1),
            np.linalg.norm(np.asarray(x), axis=-1),
            rtol=1e-5,
        )

    def test_rope_position_zero_is_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 2, 8))
        y = M.rope(x, jnp.zeros((1, 1), jnp.int32), 10000.0)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)
