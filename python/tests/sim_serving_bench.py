"""Offline accounting simulation of `cargo bench --bench serving`.

Reproduces, bit-for-bit, the DETERMINISTIC fields of the bench's
`BENCH_serving.json` records: the open-loop drive of the Rust scheduler
(`coordinator::scheduler::plan`) through `testutil::schedsim`, in the
bench's regime — a KV pool far larger than the live set (admission always
passes, registration never fails), prefix caching off, no swaps or faults.
In that regime the schedule is a pure function of the scheduler's
prefill-priority / chunk-window / interleave / decode rules and the
arrival script, so this mirror reimplements exactly those rules and the
simulator's token-weighted clock (prefill of T tokens costs T, a chunk
window costs its take, decode and idle steps cost 1).

Token VALUES are irrelevant to latency, so no Philox mirroring is needed
here (contrast `sim_prefixcache_bench.py`).

Timing fields (`median_ns` etc.) are bench-only: running `cargo bench
--bench serving` on a toolbox overwrites this snapshot with `source:
"bench"` records that add them (the shared fields must not change — if
they do, the mirror or the Rust code regressed).

Usage:  cd python && python tests/sim_serving_bench.py [out.json]
"""

import json
import sys

REQUESTS = 48
LONG_PROMPT = 60
MAX_CONCURRENCY = 8
PREFILL_B = 4
MAX_T = 64  # largest prefill T bucket
DECODE_MAX_B = 8  # largest decode bucket


def prompt_len(i):
    return LONG_PROMPT if i % 8 == 3 else 6 + (i * 5) % 19


def gen_len(i):
    return 2 + (i * 3) % 7


class Seq:
    def __init__(self, rid):
        self.id = rid
        self.plen = prompt_len(rid)
        self.max_new = gen_len(rid)
        self.prefilled = 0
        self.times = []  # weighted timestamp of each emitted token


def plan(waiting, running, chunk, interleave, now):
    """Mirror of scheduler::plan for uniform priority, zero cached prefix,
    and an admission probe that always passes."""
    deferred = None
    if len(running) < MAX_CONCURRENCY:
        headroom = MAX_CONCURRENCY - len(running)
        chunk_eff = min(chunk, MAX_T)
        if chunk_eff > 0 and waiting:
            head = waiting[0]
            remaining = (
                head.plen - head.prefilled if head.prefilled > 0 else head.plen
            )
            if remaining > chunk_eff:
                if interleave and now % 2 == 1:
                    deferred = head
                else:
                    return ("chunk", head)
        chosen = []
        for s in waiting:
            if deferred is not None and s.id == deferred.id:
                continue
            if s.prefilled > 0:
                if s.plen - s.prefilled > chunk_eff:
                    continue
            elif s.plen > MAX_T:
                continue
            chosen.append(s)
            if len(chosen) == min(PREFILL_B, headroom):
                break
        if chosen:
            return ("prefill", chosen)
    if not running:
        if deferred is not None:
            return ("chunk", deferred)
        return ("idle", None)
    return ("decode", running[:DECODE_MAX_B])


def drive(interval, chunk, interleave):
    arrivals = [(i * interval, Seq(i)) for i in range(REQUESTS)]
    waiting, running, done = [], [], []
    clock = wtime = 0
    nxt = 0
    chunk_windows = 0
    steps = 0
    while nxt < len(arrivals) or waiting or running:
        while nxt < len(arrivals) and arrivals[nxt][0] <= clock:
            waiting.append(arrivals[nxt][1])
            nxt += 1
        if not waiting and not running:
            clock += 1
            wtime += 1
            continue
        clock += 1
        kind, what = plan(waiting, running, chunk, interleave, clock)
        if kind == "chunk":
            s = what
            waiting.remove(s)
            take = min(min(chunk, MAX_T), max(0, s.plen - 1 - s.prefilled))
            s.prefilled += take
            chunk_windows += 1
            wtime += max(take, 1)
            waiting.insert(0, s)
        elif kind == "prefill":
            for s in what:
                waiting.remove(s)
            longest = max(
                (s.plen - s.prefilled if s.prefilled > 0 else s.plen)
                for s in what
            )
            wtime += max(longest, 1)
            for s in what:
                s.times.append(wtime)
                if len(s.times) >= s.max_new:
                    done.append(s)
                else:
                    running.append(s)
        elif kind == "decode":
            wtime += 1
            retired = []
            for s in what:
                s.times.append(wtime)
                if len(s.times) >= s.max_new:
                    retired.append(s)
            for s in retired:
                running.remove(s)
                done.append(s)
        else:  # idle — unreachable in the big-pool regime
            raise AssertionError("idle step with work pending")
        steps += 1
        assert steps <= 20_000, "starvation guard"
    return done, chunk_windows


def pct(sorted_vals, q):
    return sorted_vals[min(int(len(sorted_vals) * q), len(sorted_vals) - 1)]


def record(interval, name, chunk, interleave):
    done, windows = drive(interval, chunk, interleave)
    assert len(done) == REQUESTS
    ttft, short_ttft, itl, makespan = [], [], [], 0
    for s in done:
        assert len(s.times) == s.max_new, f"request {s.id} token budget"
        ttft.append(s.times[0])
        if s.plen < 32:
            short_ttft.append(s.times[0])
        itl.extend(b - a for a, b in zip(s.times, s.times[1:]))
        makespan = max(makespan, s.times[-1])
    ttft.sort()
    short_ttft.sort()
    itl.sort()
    return {
        "scenario": name,
        "source": "accounting-sim",
        "arrival_interval": interval,
        "chunk": chunk,
        "interleave": interleave,
        "requests": REQUESTS,
        "completed": len(done),
        "ttft_p50_w": pct(ttft, 0.5),
        "ttft_p95_w": pct(ttft, 0.95),
        "short_ttft_p95_w": pct(short_ttft, 0.95),
        "itl_p50_w": pct(itl, 0.5),
        "itl_p95_w": pct(itl, 0.95),
        "makespan_w": makespan,
        "chunk_windows": windows,
    }


def main():
    records = []
    for interval in (1, 2, 4):
        pair = []
        for name, chunk, interleave in (
            ("whole", 0, False),
            ("chunked-interleave", 16, True),
        ):
            r = record(interval, name, chunk, interleave)
            pair.append(r)
            records.append(r)
            print(
                f"interval {interval} {name:<18} "
                f"ttft p50/p95 {r['ttft_p50_w']:>4}/{r['ttft_p95_w']:>4} | "
                f"short p95 {r['short_ttft_p95_w']:>4} | "
                f"itl p50/p95 {r['itl_p50_w']:>2}/{r['itl_p95_w']:>3} | "
                f"makespan {r['makespan_w']:>5} | windows {r['chunk_windows']}"
            )
        # The bench's regression bar, checked here too.
        assert pair[1]["short_ttft_p95_w"] <= pair[0]["short_ttft_p95_w"], (
            f"interval {interval}: chunked short p95 regressed"
        )

    out = sys.argv[1] if len(sys.argv) > 1 else "BENCH_serving.json"
    body = ",\n".join(
        "    " + json.dumps(r, separators=(", ", ": ")) for r in records
    )
    config = json.dumps(
        {"requests": REQUESTS, "long_prompt": LONG_PROMPT},
        separators=(", ", ": "),
    )
    text = (
        '{\n  "bench": "serving",\n  "schema_version": 2,\n'
        '  "source": "accounting-sim",\n'
        '  "config": ' + config + ",\n"
        '  "results": [\n' + body + "\n  ]\n}\n"
    )
    with open(out, "w") as f:
        f.write(text)
    print(f"\nwrote {out} ({len(records)} records)")


if __name__ == "__main__":
    main()
