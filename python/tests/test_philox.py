"""Philox4x32-10 correctness: known-answer tests + statistical sanity.

The KAT vectors are from the Random123 reference distribution (Salmon et al.,
SC'11, kat_vectors file).  The same vectors are asserted by the Rust
implementation (rust/src/sampling/philox.rs) — together they pin the two
implementations to each other and to the published algorithm.
"""

import numpy as np
import pytest

from compile import philox


def run1(ctr, key, rounds=10):
    out = philox.philox4x32(
        np.uint32(ctr[0]), np.uint32(ctr[1]), np.uint32(ctr[2]), np.uint32(ctr[3]),
        np.uint32(key[0]), np.uint32(key[1]), rounds=rounds,
    )
    return tuple(int(np.asarray(x)) for x in out)


# (counter, key, expected output) — Random123 kat_vectors, philox4x32x10.
KAT = [
    ((0x00000000,) * 4, (0x00000000,) * 2,
     (0x6627E8D5, 0xE169C58D, 0xBC57AC4C, 0x9B00DBD8)),
    ((0xFFFFFFFF,) * 4, (0xFFFFFFFF,) * 2,
     (0x408F276D, 0x41C83B0E, 0xA20BC7C6, 0x6D5451FD)),
    ((0x243F6A88, 0x85A308D3, 0x13198A2E, 0x03707344),
     (0xA4093822, 0x299F31D0),
     (0xD16CFE09, 0x94FDCCEB, 0x5001E420, 0x24126EA1)),
]


@pytest.mark.parametrize("ctr,key,expected", KAT)
def test_kat_vectors(ctr, key, expected):
    assert run1(ctr, key) == expected


def test_deterministic_and_counter_sensitive():
    base = run1((1, 2, 3, 4), (5, 6))
    assert run1((1, 2, 3, 4), (5, 6)) == base
    # flipping any counter word or key word changes the output
    for pos in range(4):
        ctr = [1, 2, 3, 4]
        ctr[pos] ^= 1
        assert run1(tuple(ctr), (5, 6)) != base
    assert run1((1, 2, 3, 4), (5, 7)) != base
    assert run1((1, 2, 3, 4), (4, 6)) != base


def test_vectorized_matches_scalar():
    i = np.arange(17, dtype=np.uint32)
    out = philox.philox4x32(i, np.uint32(9), np.uint32(0), np.uint32(3),
                            np.uint32(11), np.uint32(22))
    x0 = np.asarray(out[0])
    for k in range(17):
        s = run1((k, 9, 0, 3), (11, 22))
        assert x0[k] == s[0]


def test_uniform_open01_range_and_mean():
    i = np.arange(200_000, dtype=np.uint32)
    u = np.asarray(philox.uniform_at(i, np.uint32(0), 0, 1, 2))
    assert (u > 0).all() and (u < 1).all()
    assert abs(u.mean() - 0.5) < 0.005
    # uniform second moment E[u^2] = 1/3
    assert abs((u ** 2).mean() - 1 / 3) < 0.005


def test_uniform_extremes_are_finite_gumbel():
    # u = 0 and u = 1 are impossible by construction; the extreme 32-bit
    # words must map to finite Gumbel values.
    g_lo = -np.log(-np.log(np.asarray(philox.uniform_open01(np.uint32(0)))))
    g_hi = -np.log(-np.log(np.asarray(philox.uniform_open01(np.uint32(0xFFFFFFFF)))))
    assert np.isfinite(g_lo) and np.isfinite(g_hi)


def test_gumbel_moments():
    # Gumbel(0,1): mean = Euler-Mascheroni, var = pi^2/6.
    i = np.arange(200_000, dtype=np.uint32)
    g = np.asarray(philox.gumbel_at(i, np.uint32(0), 0, 123, 456))
    assert abs(g.mean() - 0.5772) < 0.01
    assert abs(g.var() - np.pi ** 2 / 6) < 0.03


def test_streams_are_decorrelated():
    i = np.arange(50_000, dtype=np.uint32)
    a = np.asarray(philox.uniform_at(i, np.uint32(0), 0, 1, 2,
                                     stream=philox.STREAM_GUMBEL))
    b = np.asarray(philox.uniform_at(i, np.uint32(0), 0, 1, 2,
                                     stream=philox.STREAM_ROW_UNIFORM))
    r = np.corrcoef(a, b)[0, 1]
    assert abs(r) < 0.02
