"""Grouped / online / distributed Gumbel-Max exactness (paper §D.1-D.4).

Lemma D.2 (group factorization), Lemma D.3 (binary merge) and Theorem D.4
(hierarchical exactness) are distribution-level statements; we verify them
with chi-squared goodness-of-fit plus structural checks (log-mass
bookkeeping, pathwise shard merging).
"""

import jax
import jax.numpy as jnp
import numpy as np
from scipy import stats

from compile.kernels import flash_sampling as fs
from compile.kernels import grouped, ref

V, D, ROWS = 256, 32, 50
SEED = (77, 88)


def _setup(key=1, scale=0.5):
    kh, kw = jax.random.split(jax.random.PRNGKey(key))
    h1 = jax.random.normal(kh, (1, D), jnp.float32)
    w = jax.random.normal(kw, (V, D), jnp.float32) * scale
    h = jnp.tile(h1, (ROWS, 1))
    probs = np.asarray(ref.softmax_probs(h1, w))[0]
    return h, w, probs


def _chisq(samples, probs):
    counts = np.bincount(samples, minlength=len(probs))
    expected = probs * len(samples)
    order = np.argsort(expected)
    exp_s, cnt_s = expected[order], counts[order]
    bins_e, bins_c, acc_e, acc_c = [], [], 0.0, 0.0
    for e, c in zip(exp_s, cnt_s):
        acc_e += e
        acc_c += c
        if acc_e >= 5:
            bins_e.append(acc_e)
            bins_c.append(acc_c)
            acc_e = acc_c = 0.0
    if acc_e:
        bins_e[-1] += acc_e
        bins_c[-1] += acc_c
    be, bc = np.asarray(bins_e), np.asarray(bins_c)
    chi2 = ((bc - be) ** 2 / be).sum()
    return stats.chi2.sf(chi2, df=len(be) - 1)


def _collect(fn, n=8000):
    out, step = [], 0
    while len(out) * ROWS < n:
        out.append(np.asarray(fn(step)))
        step += 1
    return np.concatenate(out)[:n]


class TestParallelGroupGumbelMax:
    def test_distribution_exact(self):
        h, w, probs = _setup()
        samples = _collect(
            lambda s: grouped.parallel_group_sample(h, w, SEED, step=s,
                                                    group_size=32)[0]
        )
        p = _chisq(samples, probs)
        assert p > 0.001, f"Alg I.2 rejected: p={p}"

    def test_log_z_exact(self):
        h, w, _ = _setup()
        _, lz = grouped.parallel_group_sample(h, w, SEED, group_size=64)
        np.testing.assert_allclose(
            np.asarray(lz), np.asarray(ref.log_z(h, w)), rtol=1e-5
        )

    def test_group_size_invariance_of_distribution(self):
        # Different groupings are different factorizations of the SAME
        # categorical: each must pass GoF against the same probs.
        h, w, probs = _setup(key=2)
        for gs in (16, 64, 128):
            samples = _collect(
                lambda s, gs=gs: grouped.parallel_group_sample(
                    h, w, SEED, step=s, group_size=gs
                )[0],
                n=6000,
            )
            p = _chisq(samples, probs)
            assert p > 0.001, f"group_size={gs}: p={p}"


class TestOnlineGroupGumbelMax:
    def test_distribution_exact(self):
        h, w, probs = _setup(key=3)
        samples = _collect(
            lambda s: grouped.online_group_sample(h, w, SEED, step=s,
                                                  group_size=64)[0]
        )
        p = _chisq(samples, probs)
        assert p > 0.001, f"Alg I.3 rejected: p={p}"

    def test_running_log_mass_is_exact(self):
        h, w, _ = _setup(key=4)
        _, lrun = grouped.online_group_sample(h, w, SEED, group_size=32)
        np.testing.assert_allclose(
            np.asarray(lrun), np.asarray(ref.log_z(h, w)), rtol=1e-5
        )

    def test_single_group_degenerates_to_gumbel_max(self):
        h, w, _ = _setup(key=5)
        z, _ = grouped.online_group_sample(h, w, SEED, group_size=V)
        expect = ref.gumbel_max_sample(h, w, SEED)
        np.testing.assert_array_equal(np.asarray(z), np.asarray(expect))


class TestDistributedSampling:
    def _shards(self, h, w, n, step=0):
        vs = V // n
        out = []
        for r in range(n):
            m, s, lm = fs.shard_candidates(
                h, w[r * vs : (r + 1) * vs], r * vs, SEED, step=step, tile_v=64
            )
            out.append((m, s, lm))
        return out

    def test_pathwise_merge_equals_single_rank(self):
        h, w, _ = _setup(key=6)
        for n in (2, 4, 8):
            shards = self._shards(h, w, n, step=5)
            got = grouped.distributed_sample_pathwise(
                [(m, s) for m, s, _ in shards]
            )
            expect = ref.gumbel_max_sample(h, w, SEED, step=5)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))

    def test_distribution_level_merge_exact(self):
        h, w, probs = _setup(key=7)

        def draw(step):
            shards = self._shards(h, w, 4, step=step)
            z, _ = grouped.distributed_sample(
                [(s, lm) for _, s, lm in shards], SEED, step=step
            )
            return z

        samples = _collect(draw, n=6000)
        p = _chisq(samples, probs)
        assert p > 0.001, f"Alg I.4 rejected: p={p}"

    def test_communication_payload_is_o1_per_rank(self):
        # Structural: the shard summary is 3 scalars per row per rank,
        # independent of shard vocabulary size.
        h, w, _ = _setup(key=8)
        m, s, lm = fs.shard_candidates(h, w[:128], 0, SEED, tile_v=32)
        assert m.shape == (ROWS,) and s.shape == (ROWS,) and lm.shape == (ROWS,)

    def test_log_z_from_shard_masses(self):
        h, w, _ = _setup(key=9)
        shards = self._shards(h, w, 4)
        _, lz = grouped.distributed_sample(
            [(s, lm) for _, s, lm in shards], SEED
        )
        np.testing.assert_allclose(
            np.asarray(lz), np.asarray(ref.log_z(h, w)), rtol=1e-5
        )


class TestGroupLogMasses:
    def test_masses_factorize(self):
        """sum_k exp(L_k) == Z regardless of grouping (Lemma D.1)."""
        h, w, _ = _setup(key=10)
        z = np.asarray(ref.log_z(h, w))
        for gs in (8, 32, 128):
            lm = np.asarray(ref.group_log_masses(h, w, gs))
            np.testing.assert_allclose(
                np.log(np.exp(lm - lm.max(1, keepdims=True)).sum(1))
                + lm.max(1),
                z,
                rtol=1e-5,
            )

    def test_zero_mass_group_is_neg_inf(self):
        h, w, _ = _setup(key=11)
        bias = jnp.full((V,), -jnp.inf).at[:64].set(0.0)  # only group 0 lives
        lm = np.asarray(ref.group_log_masses(h, w, 64, bias=bias))
        assert np.isfinite(lm[:, 0]).all()
        assert np.isneginf(lm[:, 1:]).all()
