"""Offline accounting simulation of `cargo bench --bench prefixcache`.

Reproduces, bit-for-bit, the DETERMINISTIC fields of the bench's
`BENCH_prefixcache.json` records — workload generation (the Rust
`WorkloadGen` Philox streams, mirrored through `compile/philox.py`, whose
cross-language vectors are pinned by `test_philox.py`), the radix-tree
full-block hit accounting, and the `gpusim::tpot` prefill-time model — so
a provisional snapshot can be committed from a box without a Rust
toolchain.  Timing fields and the LRU-pressure scenario are bench-only:
running `cargo bench --bench prefixcache` on a toolbox overwrites this
snapshot with `source: "bench"` records that add them (the shared fields
must not change — if they do, the mirror or the Rust code regressed).

Usage:  cd python && python tests/sim_prefixcache_bench.py [out.json]
"""

import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import philox  # noqa: E402

SEED = 0xCAFE
SEED_LO, SEED_HI = np.uint32(SEED & 0xFFFFFFFF), np.uint32(SEED >> 32)
VOCAB = 2048
BLOCK = 16


def u(stream, i, b):
    """Rust WorkloadGen::u — Philox counter (i, b, stream, 0)."""
    x0, _, _, _ = philox.philox4x32(
        np.uint32(i), np.uint32(b), np.uint32(stream), np.uint32(0),
        SEED_LO, SEED_HI,
    )
    return np.float32(philox.uniform_open01(x0))


def token(stream, i, j):
    return int(np.float32(u(stream, i, j)) * np.float32(VOCAB)) % VOCAB


def draw_uniform(lo, hi, uu):
    return lo + int(np.float32(hi - lo + 1) * np.float32(uu))


def shared_prefix_prompt(sp, i):
    users = max(sp["users"], 1)
    user, turn = i % users, i // users
    sysid = user % max(sp["num_prefixes"], 1)
    prompt = [token(20, sysid, j) for j in range(sp["prefix_len"])]
    for t in range(turn + 1):
        idx = user * 1024 + t
        tl = sp["turn_len"]
        chunk = tl[1] if tl[0] == "Fixed" else draw_uniform(
            tl[1], tl[2], u(22, idx, 0)
        )
        chunk = max(chunk, 1)
        prompt += [token(21, idx, j) for j in range(chunk)]
    return prompt


def unique_prompt(i):
    plen = max(draw_uniform(64, 192, u(11, i, 0)), 1)
    return [token(13, i, j) for j in range(plen)]


def drive(prompts):
    """Sequential register/insert accounting — mirrors the bench's
    `drive()` hit computation (the radix tree's chain matching reduces to
    longest-inserted-full-block-prefix because inserts always publish
    whole chains from the root)."""
    cache = set()
    prefill = cached = 0
    for p in prompts:
        cap = (len(p) - 1) // BLOCK
        matched = 0
        while matched < cap and tuple(p[: (matched + 1) * BLOCK]) in cache:
            matched += 1
        prefill += len(p)
        cached += matched * BLOCK
        for j in range(1, len(p) // BLOCK + 1):
            cache.add(tuple(p[: j * BLOCK]))
    return prefill, cached


def prefill_time(prompt_tokens, cached_fraction):
    """gpusim::tpot::ModelSpec::prefill_time for QWEN3_8B on B200."""
    params, tp, n_layers = 8.2e9, 1, 36
    bf16_flops, mfu = 2250e12, 0.5
    hbm_bw, bw_eff = 8.0e12, 0.85
    launch, kernels_per_layer, host = 4.0e-6, 8.0, 130.0e-6
    uncached = prompt_tokens * (1.0 - min(max(cached_fraction, 0.0), 1.0))
    compute = 2.0 * params * uncached / tp / (bf16_flops * mfu)
    weight_stream = params * 2.0 / tp / (hbm_bw * bw_eff)
    return max(compute, weight_stream) + n_layers * kernels_per_layer * launch + host


SCENARIOS = [
    {
        "name": "multi-turn-hit-heavy",
        "num_blocks": 4096,
        "mode": {"num_prefixes": 4, "prefix_len": 64, "users": 8,
                 "turn_len": ("Fixed", 16)},
        "requests": 64,
    },
    {
        "name": "system-prompt-fanout",
        "num_blocks": 4096,
        "mode": {"num_prefixes": 2, "prefix_len": 96, "users": 16,
                 "turn_len": ("Uniform", 16, 48)},
        "requests": 16,
    },
    {
        "name": "unique-cold",
        "num_blocks": 4096,
        "mode": None,
        "requests": 32,
    },
]


def record(sc):
    if sc["mode"]:
        prompts = [shared_prefix_prompt(sc["mode"], i)
                   for i in range(sc["requests"])]
    else:
        prompts = [unique_prompt(i) for i in range(sc["requests"])]
    prefill, cached = drive(prompts)
    hit = cached / max(prefill, 1)
    mean_prompt = prefill / len(prompts)
    # Modeled at a production-size prompt (the workload's own prompts are
    # artifact-bucket-sized and sit below the weight-stream floor).
    prod_prompt = 2048
    cold_ms = prefill_time(prod_prompt, 0.0) * 1e3
    hit_ms = prefill_time(prod_prompt, hit) * 1e3
    m = sc["mode"]
    if m:
        tl = m["turn_len"]
        tl_str = (f"Fixed({tl[1]})" if tl[0] == "Fixed"
                  else f"Uniform({tl[1]}, {tl[2]})")
        np_, pl, us = m["num_prefixes"], m["prefix_len"], m["users"]
    else:
        tl_str, np_, pl, us = "-", 0, 0, 0
    fields = [
        ("scenario", f'"{sc["name"]}"'),
        ("source", '"accounting-sim"'),
        ("block_size", str(BLOCK)),
        ("num_blocks", str(sc["num_blocks"])),
        ("num_prefixes", str(np_)),
        ("prefix_len", str(pl)),
        ("users", str(us)),
        ("turn_len", f'"{tl_str}"'),
        ("requests", str(len(prompts))),
        ("prefill_tokens", str(prefill)),
        ("cached_prefill_tokens", str(cached)),
        ("hit_rate", f"{hit:.4f}"),
        ("cached_token_reduction", f"{hit:.4f}"),
        ("evicted_blocks", "0"),
        ("leaked_blocks", "0"),
        ("mean_prompt_tokens", f"{mean_prompt:.1f}"),
        ("model", '"Qwen3-8B"'),
        ("gpu", '"B200"'),
        ("modeled_prompt_tokens", str(prod_prompt)),
        ("modeled_prefill_cold_ms", f"{cold_ms:.3f}"),
        ("modeled_prefill_hit_ms", f"{hit_ms:.3f}"),
        ("modeled_prefill_reduction", f"{1.0 - hit_ms / cold_ms:.4f}"),
    ]
    body = ", ".join(f'"{k}": {v}' for k, v in fields)
    return "{" + body + "}"


def main():
    out = sys.argv[1] if len(sys.argv) > 1 else "../BENCH_prefixcache.json"
    records = [record(sc) for sc in SCENARIOS]
    text = '{\n  "bench": "prefixcache",\n  "schema_version": 1,\n  "results": [\n'
    for i, r in enumerate(records):
        text += "    " + r + (",\n" if i + 1 < len(records) else "\n")
    text += "  ]\n}\n"
    with open(out, "w") as f:
        f.write(text)
    print(text)
    # Acceptance bar (mirrors the bench's asserts).
    import json
    data = json.loads(text)
    hitheavy = data["results"][0]
    assert hitheavy["cached_token_reduction"] >= 0.5, hitheavy
    assert data["results"][2]["cached_prefill_tokens"] == 0
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
