"""Distributional exactness — the paper's §4.6 kernel-level verification.

Chi-squared goodness-of-fit of FlashSampling draws against the exact
categorical probabilities (paper: V=512, 10,000 samples, "no statistically
significant difference").  We replicate that protocol and additionally test
the baseline sampler and agreement between samplers.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from scipy import stats

from compile.kernels import flash_sampling as fs
from compile.kernels import ref

V = 512
D = 32
N_SAMPLES = 10_000
ROWS = 50  # draw ROWS independent samples per kernel call (distinct b => i.i.d.)


def _dist_setup(key=0, scale=0.6):
    kh, kw = jax.random.split(jax.random.PRNGKey(key))
    h1 = jax.random.normal(kh, (1, D), jnp.float32)
    w = jax.random.normal(kw, (V, D), jnp.float32) * scale
    h = jnp.tile(h1, (ROWS, 1))  # same distribution in every row
    probs = np.asarray(ref.softmax_probs(h1, w))[0]
    return h, w, probs


def _collect(sampler, n=N_SAMPLES):
    out = []
    step = 0
    while len(out) * ROWS < n:
        out.append(np.asarray(sampler(step)))
        step += 1
    return np.concatenate(out)[:n]


def _chisq_pvalue(samples, probs):
    counts = np.bincount(samples, minlength=V)
    expected = probs * len(samples)
    # Merge tiny-expectation bins (standard validity rule E>=5).
    order = np.argsort(expected)
    exp_s, cnt_s = expected[order], counts[order]
    bins_e, bins_c = [], []
    acc_e = acc_c = 0.0
    for e, c in zip(exp_s, cnt_s):
        acc_e += e
        acc_c += c
        if acc_e >= 5:
            bins_e.append(acc_e)
            bins_c.append(acc_c)
            acc_e = acc_c = 0.0
    if acc_e > 0:
        bins_e[-1] += acc_e
        bins_c[-1] += acc_c
    bins_e = np.asarray(bins_e)
    bins_c = np.asarray(bins_c)
    chi2 = ((bins_c - bins_e) ** 2 / bins_e).sum()
    return stats.chi2.sf(chi2, df=len(bins_e) - 1)


class TestChiSquaredGoodnessOfFit:
    def test_flash_sampling_matches_exact_distribution(self):
        h, w, probs = _dist_setup()
        samples = _collect(
            lambda s: fs.flash_sample(h, w, (11, 22), step=s, tile_v=128).sample
        )
        p = _chisq_pvalue(samples, probs)
        assert p > 0.001, f"chi-squared rejected exactness: p={p}"

    def test_baseline_multinomial_matches_exact_distribution(self):
        h, w, probs = _dist_setup()
        samples = _collect(
            lambda s: ref.multinomial_sample(h, w, (11, 22), step=s)
        )
        p = _chisq_pvalue(samples, probs)
        assert p > 0.001, f"baseline sampler off: p={p}"

    def test_flash_sampling_with_temperature(self):
        h, w, _ = _dist_setup()
        tau = 1.7
        probs = np.asarray(ref.softmax_probs(h[:1], w, temperature=tau))[0]
        samples = _collect(
            lambda s: fs.flash_sample(
                h, w, (3, 4), step=s, temperature=tau, tile_v=128
            ).sample,
            n=8000,
        )
        p = _chisq_pvalue(samples, probs)
        assert p > 0.001, f"temperature path off: p={p}"

    def test_detects_a_wrong_sampler(self):
        """Power check: the GoF machinery must reject a biased sampler."""
        h, w, probs = _dist_setup()
        # greedy 'sampler' (temperature ~ 0) is grossly non-categorical
        samples = _collect(
            lambda s: fs.flash_sample(
                h, w, (5, 6), step=s, temperature=1e-4, tile_v=128
            ).sample,
            n=4000,
        )
        p = _chisq_pvalue(samples, probs)
        assert p < 1e-6


class TestIndependence:
    def test_rows_are_independent(self):
        # Correlation across rows of the same call should be null:
        # different b => different Philox counters.
        h, w, _ = _dist_setup()
        draws = np.stack(
            [
                np.asarray(
                    fs.flash_sample(h, w, (9, 9), step=s, tile_v=128).sample
                )
                for s in range(200)
            ]
        )  # [steps, ROWS]
        a, b = draws[:, 0], draws[:, 1]
        # identical marginals but independent draws: match rate ≈ sum p_i^2
        _, _, probs = _dist_setup()
        expected_match = (probs ** 2).sum()
        observed_match = (a == b).mean()
        se = np.sqrt(expected_match * (1 - expected_match) / len(a))
        assert abs(observed_match - expected_match) < 5 * se + 0.01

    def test_steps_are_independent(self):
        h, w, probs = _dist_setup()
        s0 = np.asarray(fs.flash_sample(h, w, (9, 9), step=0, tile_v=128).sample)
        s1 = np.asarray(fs.flash_sample(h, w, (9, 9), step=1, tile_v=128).sample)
        match = (s0 == s1).mean()
        assert match < 0.5  # far from deterministic repetition
