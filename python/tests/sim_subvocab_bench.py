#!/usr/bin/env python3
"""Cross-language certified sub-vocabulary decode mirror + bench.

Independently reimplements the `SimReplica` subvocab mirror leg of
`repro subvocab-identity` (rust/src/repro/subvocab_identity.rs, leg 4):
the trace-identity mirror workload (6 closed-loop requests,
`prompt_len = 24 + (id % 3) * 8`, `max_new = 3 + (id % 3)`, prefix
cache off, `Lifecycle` level) with the subvocab event model on
(router/sim.rs: one event per decode step, fallback iff the batch
counter `cstep % 4 == 0`, attributed to the first running row, 4
candidate tiles of 16) — and re-derives the canonical JSONL stream plus
its FNV-1a 64 digest byte-for-byte.

It then re-derives the modeled tile-skip speedup from an independent
reimplementation of the `gpusim` kernel-chain arithmetic
(rust/src/gpusim/kernelchain.rs `chain` / `chain_subvocab` /
`subvocab_speedup`), prices the engine's honest fallback protocol
(`sub + fallback_rate * full` per step), and writes `BENCH_subvocab.json`
(schema v2) for the `flashsampling benchdiff` perf gate.

Usage:
    python3 python/tests/sim_subvocab_bench.py [BENCH_subvocab.json]
    python3 python/tests/sim_subvocab_bench.py --check subvocab-identity.csv

With `--check`, asserts bitwise digest equality against the Rust-side
`sim-subvocab` anchor row — the CI cross-language gate.
"""

import json
import math
import sys

# FNV-1a 64 (rust/src/trace/mod.rs FNV_OFFSET / FNV_PRIME).
FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
MASK64 = (1 << 64) - 1

# Mirror-leg workload + SimReplicaConfig defaults (keep in lockstep with
# subvocab_identity.rs `mirror_run_subvocab` and router/sim.rs).
NUM_REQUESTS = 6
PREFILL_B = 4
DECODE_MAX_B = 8
MAX_CONCURRENCY = 8

# The subvocab event rule (router/sim.rs do_decode): per decode step,
# fallback iff cstep % 4 == 0, args active=4 / skipped=12.
SUB_ACTIVE, SUB_SKIPPED = 4, 12
FALLBACK_PERIOD = 4


def prompt_len(rid):
    return 24 + (rid % 3) * 8


def max_new(rid):
    return 3 + (rid % 3)


def sim_token(rid, index):
    """router/sim.rs `sim_token`: deterministic model stand-in."""
    return (rid * 31 + (index + 1) * 7) % 2039


class Recorder:
    """Canonical-line serializer + incremental FNV-1a digest
    (trace/mod.rs `TraceEvent::canonical_line`)."""

    def __init__(self):
        self.seq = 0
        self.digest = FNV_OFFSET

    def emit(self, step, rid, ev, args):
        parts = ['"seq":%d' % self.seq, '"step":%d' % step,
                 '"id":%d' % rid, '"ev":"%s"' % ev]
        for key, val in args:
            if isinstance(val, str):
                parts.append('"%s":"%s"' % (key, val))
            else:
                parts.append('"%s":%d' % (key, val))
        line = "{" + ",".join(parts) + "}"
        self.seq += 1
        for byte in line.encode("utf-8") + b"\n":
            self.digest = ((self.digest ^ byte) * FNV_PRIME) & MASK64


def run_mirror():
    """The SimReplica FIFO batcher at Lifecycle level with the subvocab
    event model on.  Returns (recorder, subvocab_steps, fallbacks)."""
    rec = Recorder()
    clock = 0
    cstep = 0
    waiting = []
    running = []
    sub_steps = 0
    fallbacks = 0
    for rid in range(NUM_REQUESTS):
        rec.emit(clock, rid, "submit",
                 [("prompt_len", prompt_len(rid)), ("max_new", max_new(rid))])
        waiting.append({"id": rid, "gen": 0})
    while waiting or running:
        clock += 1
        if len(running) < MAX_CONCURRENCY and waiting:
            batch = []
            while (waiting and len(batch) < PREFILL_B
                   and len(running) + len(batch) < MAX_CONCURRENCY):
                batch.append(waiting.pop(0))
            snap = cstep
            cstep += 1
            for row, seq in enumerate(batch):
                rec.emit(clock, seq["id"], "prefill",
                         [("prompt_len", prompt_len(seq["id"]))])
                tok = sim_token(seq["id"], 0)
                seq["gen"] = 1
                rec.emit(clock, seq["id"], "first_token",
                         [("row", row), ("cstep", snap), ("token", tok)])
            for seq in batch:
                if seq["gen"] >= max_new(seq["id"]):
                    rec.emit(clock, seq["id"], "finish",
                             [("reason", "max_tokens"), ("tokens", seq["gen"])])
                else:
                    running.append(seq)
        elif running:
            snap = cstep
            cstep += 1
            # The subvocab event precedes the step's decode_token events
            # (router/sim.rs emits it before the row loop).
            sub_steps += 1
            ev = "subvocab_skip"
            if snap % FALLBACK_PERIOD == 0:
                ev = "subvocab_fallback"
                fallbacks += 1
            rec.emit(clock, running[0]["id"], ev,
                     [("active", SUB_ACTIVE), ("skipped", SUB_SKIPPED)])
            for row in range(min(len(running), DECODE_MAX_B)):
                seq = running[row]
                tok = sim_token(seq["id"], seq["gen"])
                seq["gen"] += 1
                rec.emit(clock, seq["id"], "decode_token",
                         [("row", row), ("cstep", snap), ("token", tok)])
            i = 0
            while i < len(running):
                if running[i]["gen"] >= max_new(running[i]["id"]):
                    seq = running.pop(i)
                    rec.emit(clock, seq["id"], "finish",
                             [("reason", "max_tokens"), ("tokens", seq["gen"])])
                else:
                    i += 1
        assert clock < 1000, "mirror livelock"
    return rec, sub_steps, fallbacks


# --- kernel-chain arithmetic mirror (rust/src/gpusim/kernelchain.rs) ---

BF16 = 2.0
BW_EFF_TRITON = 0.78
GAP_FUSED_STAGE2 = 1.5e-6
FUSED_TILE_V = 2048

# specs.rs B200.
B200 = {"hbm_bw": 8.0e12, "bf16_flops": 2250e12, "launch_overhead": 4.0e-6}

# Engine-side active fraction: SUB_TILE_SLOTS (4) tiles of SUB_TILE_V
# (128) over the 2048-token toy vocab — and identically the sim event
# model's 4-of-16 tiles.
ACTIVE_FRAC = 0.25

# Paper workload the Rust unit test prices (`Workload::small(8)`).
BATCH, D_MODEL, VOCAB = 8, 4096, 151_936


def compute_efficiency(batch):
    return 0.45 * batch / (batch + 64.0)


def triton_penalty(gpu, batch):
    sat = min(batch / 256.0, 1.0)
    max_loss = 0.08 if gpu["bf16_flops"] > 2e15 else 0.38
    return 1.0 - max_loss * sat


def gemm_time(gpu, traffic, flops, batch):
    mem = traffic / (gpu["hbm_bw"] * BW_EFF_TRITON)
    eff = compute_efficiency(batch) * triton_penalty(gpu, batch)
    return max(mem, flops / (gpu["bf16_flops"] * eff))


def fused_chain_total(gpu, batch, d, vocab, active_frac=1.0):
    """`chain(FlashSampling)` at active_frac=1.0, `chain_subvocab` below
    it: W-stream traffic, GEMM flops, and the candidate buffer scale with
    the active fraction; H-stream and stage-2 structure are unchanged."""
    frac = min(max(active_frac, 1.0 / vocab), 1.0)
    b, d, va = float(batch), float(d), vocab * frac
    gemm_flops = 2.0 * b * d * va
    n_tiles = math.ceil(va / FUSED_TILE_V)
    traffic = va * d * BF16 + b * d * BF16 + b * n_tiles * 8.0
    total = gemm_time(gpu, traffic, gemm_flops, batch)
    total += gpu["launch_overhead"]
    red_bytes = b * n_tiles * 8.0 + b * 4.0
    total += 0.3e-6 + red_bytes / (gpu["hbm_bw"] * 0.5)
    total += GAP_FUSED_STAGE2
    return total


def subvocab_speedup(gpu, batch, fallback_rate):
    """kernelchain.rs `subvocab_speedup`: the honest protocol — every
    step pays the sub pass, a fallback step pays the full pass on top."""
    full = fused_chain_total(gpu, batch, D_MODEL, VOCAB)
    sub = fused_chain_total(gpu, batch, D_MODEL, VOCAB, ACTIVE_FRAC)
    return full / (sub + min(max(fallback_rate, 0.0), 1.0) * full)


def anchor_from_csv(path):
    """The `sim-subvocab,requests,events,digest` row of
    subvocab-identity.csv."""
    with open(path) as f:
        for line in f:
            if line.startswith("sim-subvocab,"):
                cells = line.strip().split(",")
                return int(cells[2]), int(cells[3], 16)
    raise SystemExit("no sim-subvocab row in %s" % path)


def main():
    rec, sub_steps, fallbacks = run_mirror()
    rec2, _, _ = run_mirror()
    assert rec.digest == rec2.digest, "mirror is not deterministic"
    # Base lifecycle stream + one subvocab event per decode step.
    base = 4 * NUM_REQUESTS + sum(max_new(r) - 1 for r in range(NUM_REQUESTS))
    assert rec.seq == base + sub_steps, (rec.seq, base, sub_steps)
    assert 0 < fallbacks < sub_steps, (fallbacks, sub_steps)
    fb_rate = fallbacks / sub_steps
    digest = "0x%016x" % rec.digest
    print("sim_subvocab_bench: %d events, digest %s, fallback %d/%d"
          % (rec.seq, digest, fallbacks, sub_steps))

    if len(sys.argv) > 2 and sys.argv[1] == "--check":
        events, anchor = anchor_from_csv(sys.argv[2])
        assert events == rec.seq, (
            "event count mismatch: rust %d, python %d" % (events, rec.seq))
        assert anchor == rec.digest, (
            "digest mismatch: rust 0x%016x, python %s" % (anchor, digest))
        print("sim_subvocab_bench: MATCHES the Rust sim-subvocab anchor")
        return

    # Model sanity pinned to the Rust unit test
    # (`subvocab_chain_models_tile_skipping`): frac=1 is the plain chain,
    # skip-heavy decode wins, all-fallback loses.
    full = fused_chain_total(B200, BATCH, D_MODEL, VOCAB)
    same = fused_chain_total(B200, BATCH, D_MODEL, VOCAB, 1.0)
    assert abs(full - same) < 1e-12
    assert subvocab_speedup(B200, BATCH, 0.0) > 1.0
    assert subvocab_speedup(B200, BATCH, 1.0) < 1.0

    records = [{
        "scenario": "sim-subvocab",
        "source": "accounting-sim",
        "requests": NUM_REQUESTS,
        "subvocab_steps": sub_steps,
        "fallbacks": fallbacks,
        "events": rec.seq,
        "digest": digest,
    }]
    for batch in (1, 8, 64):
        full = fused_chain_total(B200, batch, D_MODEL, VOCAB)
        sub = fused_chain_total(B200, batch, D_MODEL, VOCAB, ACTIVE_FRAC)
        eff = sub + fb_rate * full
        speedup = full / eff
        r = {
            "scenario": "modeled-subvocab",
            "source": "kernel-chain-model",
            "gpu": "B200",
            "batch": batch,
            "d": D_MODEL,
            "vocab": VOCAB,
            "active_frac_pct": int(ACTIVE_FRAC * 100),
            "fallback_rate_pct": round(fb_rate * 100, 1),
            "step_full_us": round(full * 1e6, 3),
            "step_effective_us": round(eff * 1e6, 3),
            "modeled_speedup_x1000": int(round(speedup * 1000)),
        }
        records.append(r)
        print("modeled B=%-3d full %.3fus effective %.3fus speedup %.3fx"
              % (batch, full * 1e6, eff * 1e6, speedup))
        assert speedup > 1.0, "tile skip lost at B=%d" % batch

    out = sys.argv[1] if len(sys.argv) > 1 else "BENCH_subvocab.json"
    body = ",\n".join(
        "    " + json.dumps(r, separators=(", ", ": ")) for r in records
    )
    config = json.dumps(
        {"requests": NUM_REQUESTS, "fallback_period": FALLBACK_PERIOD,
         "active_frac_pct": int(ACTIVE_FRAC * 100)},
        separators=(", ", ": "),
    )
    text = (
        '{\n  "bench": "subvocab",\n  "schema_version": 2,\n'
        '  "source": "accounting-sim",\n'
        '  "config": ' + config + ",\n"
        '  "results": [\n' + body + "\n  ]\n}\n"
    )
    with open(out, "w") as f:
        f.write(text)
    print("\nwrote %s (%d records)" % (out, len(records)))


if __name__ == "__main__":
    main()
