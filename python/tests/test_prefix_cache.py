"""Prefix-cache exactness: `prefill_cached` (suffix prefill over restored
prefix KV) must be **bitwise identical** to full `prefill` — the property
that makes the serving engine's automatic prefix caching exact rather than
approximate (DESIGN.md §10).

These tests run at the SERVE configuration (`ModelConfig()` — the shapes
the AOT artifacts are lowered at), not the miniature test config: bitwise
equality across two different XLA programs is an empirical property of the
backend's reduction/vectorization choices at specific shapes, and the
serve shapes are the ones the engine's caching-on/off token identity
rides on.  (At tiny shapes, e.g. d_model=32, XLA CPU picks different
reduction orders for the two programs and the outputs differ in the last
bit — exact in distribution, not in bits.)  If a backend upgrade ever
breaks these assertions, prefix caching degrades from bit-exact to
FP-perturbation-exact and the Rust engine A/B (`repro prefix-identity`)
will report the same — this file is the early alarm.

Four identities, each asserted at the bit level (uint32 views, no
tolerances):

  1. split == full:  prefill(prefix) -> prefill_cached(suffix at offset)
     reproduces prefill(whole prompt) exactly (hidden + live KV slots);
  2. mixed offsets:  one batch mixing hit rows (offset > 0) and miss rows
     (offset 0, zero cache) — exactly what the engine packs;
  3. T-invariance:   the same suffix through the t=16 and t=64 buckets is
     identical (the engine picks the smallest bucket that fits the
     longest suffix — the TTFT win must be free);
  4. decode handoff: a decode step from the cached-prefill KV state is
     bitwise the decode step from the full-prefill state.

Run alongside the other kernel tests: `cd python && pytest tests/ -q`.
"""

import jax
import numpy as np
import pytest

from compile import model as M

# The serve configuration — what aot.py lowers (see aot.SERVE_CFG).
CFG = M.ModelConfig()
B = 4
# kv block size the Rust engine uses; engine offsets are block multiples.
BLOCK = 16

_full_jit = jax.jit(M.prefill, static_argnums=0)
_cached_jit = jax.jit(M.prefill_cached, static_argnums=0)
_step_jit = jax.jit(M.decode_step, static_argnums=0)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def _bits(x):
    return np.asarray(x, np.float32).view(np.uint32)


def _pad(rows, t):
    out = np.zeros((len(rows), t), np.int32)
    for i, r in enumerate(rows):
        out[i, : len(r)] = r
    return out


def _prompts(rng, lengths):
    return [rng.randint(0, CFG.vocab, size=n).astype(np.int32) for n in lengths]


def _assert_live_kv_equal(a, b, lens):
    for row, n in enumerate(lens):
        assert np.array_equal(
            _bits(np.asarray(a)[:, row, :, :n, :]),
            _bits(np.asarray(b)[:, row, :, :n, :]),
        ), f"row {row}: KV diverged in the first {n} slots"


def test_cached_suffix_prefill_is_bitwise_identical(params):
    rng = np.random.RandomState(7)
    lens = [48, 48, 40, 37]
    prompts = _prompts(rng, lens)
    # Rows 0 and 1 share a 32-token prefix (two cache blocks).
    prompts[1][:32] = prompts[0][:32]
    t = 64
    full_k, full_v, full_h = _full_jit(
        CFG, params, _pad(prompts, t), np.array(lens, np.int32)
    )

    off = 32  # two full blocks cached per row
    pre_k, pre_v, _ = _full_jit(
        CFG, params, _pad([p[:off] for p in prompts], t),
        np.full(B, off, np.int32),
    )
    suffixes = [p[off:] for p in prompts]
    got_k, got_v, got_h = _cached_jit(
        CFG, params, pre_k, pre_v, np.full(B, off, np.int32),
        _pad(suffixes, t), np.array([len(s) for s in suffixes], np.int32),
    )
    assert np.array_equal(_bits(full_h), _bits(got_h))
    _assert_live_kv_equal(full_k, got_k, lens)
    _assert_live_kv_equal(full_v, got_v, lens)


def test_per_row_offsets_mix_hits_and_misses(params):
    rng = np.random.RandomState(11)
    lens = [60, 40, 25, 18]
    prompts = _prompts(rng, lens)
    offs = np.array([2 * BLOCK, BLOCK, 0, 0], np.int32)
    t = 64
    full_k, _, full_h = _full_jit(
        CFG, params, _pad(prompts, t), np.array(lens, np.int32)
    )
    pre_k, pre_v, _ = _full_jit(
        CFG, params,
        _pad([p[:o] if o else p[:1] for p, o in zip(prompts, offs)], t),
        np.maximum(offs, 1),
    )
    # Miss rows (offset 0) carry no cached prefix: the engine restores
    # nothing there, so their cache rows are zero.
    pre_k = np.asarray(pre_k).copy()
    pre_v = np.asarray(pre_v).copy()
    for b, o in enumerate(offs):
        if o == 0:
            pre_k[:, b] = 0.0
            pre_v[:, b] = 0.0
    suffixes = [p[o:] for p, o in zip(prompts, offs)]
    got_k, _, got_h = _cached_jit(
        CFG, params, pre_k, pre_v, offs,
        _pad(suffixes, t), np.array([len(s) for s in suffixes], np.int32),
    )
    assert np.array_equal(_bits(full_h), _bits(got_h))
    _assert_live_kv_equal(full_k, got_k, lens)


def test_same_suffix_identical_across_t_buckets(params):
    """t=16 vs t=64 executables must not perturb a single bit."""
    rng = np.random.RandomState(17)
    off = 2 * BLOCK
    lens = [off + n for n in (14, 10, 7, 1)]
    prompts = _prompts(rng, lens)
    pre_k, pre_v, _ = _full_jit(
        CFG, params, _pad([p[:off] for p in prompts], 64),
        np.full(B, off, np.int32),
    )
    suffixes = [p[off:] for p in prompts]
    slens = np.array([len(s) for s in suffixes], np.int32)
    offs = np.full(B, off, np.int32)
    k16, v16, h16 = _cached_jit(
        CFG, params, pre_k, pre_v, offs, _pad(suffixes, 16), slens
    )
    k64, v64, h64 = _cached_jit(
        CFG, params, pre_k, pre_v, offs, _pad(suffixes, 64), slens
    )
    assert np.array_equal(_bits(h16), _bits(h64))
    _assert_live_kv_equal(k16, k64, lens)
    _assert_live_kv_equal(v16, v64, lens)


def test_decode_continues_a_cached_prefill_seamlessly(params):
    rng = np.random.RandomState(19)
    lens = [40, 36, 33, 34]
    prompts = _prompts(rng, lens)
    t = 64
    full_k, full_v, _ = _full_jit(
        CFG, params, _pad(prompts, t), np.array(lens, np.int32)
    )
    off = BLOCK
    pre_k, pre_v, _ = _full_jit(
        CFG, params, _pad([p[:off] for p in prompts], t),
        np.full(B, off, np.int32),
    )
    suffixes = [p[off:] for p in prompts]
    got_k, got_v, _ = _cached_jit(
        CFG, params, pre_k, pre_v, np.full(B, off, np.int32),
        _pad(suffixes, t), np.array([len(s) for s in suffixes], np.int32),
    )
    pos = np.array(lens, np.int32)
    tok = np.array([5, 6, 7, 8], np.int32)
    ka, va, ha = _step_jit(CFG, params, full_k, full_v, pos, tok)
    kb, vb, hb = _step_jit(CFG, params, got_k, got_v, pos, tok)
    assert np.array_equal(_bits(ha), _bits(hb))
    _assert_live_kv_equal(ka, kb, [n + 1 for n in lens])
    _assert_live_kv_equal(va, vb, [n + 1 for n in lens])
