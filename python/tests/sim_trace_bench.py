#!/usr/bin/env python3
"""Cross-language flight-recorder digest mirror.

Independently reimplements the `SimReplica` mirror leg of
`repro trace-identity` (rust/src/repro/trace_identity.rs, leg 5):
6 closed-loop requests, `prompt_len = 24 + (id % 3) * 8`,
`max_new = 3 + (id % 3)`, prefix cache off, `Lifecycle` trace level —
and re-derives the canonical JSONL stream plus its FNV-1a 64 digest
byte-for-byte (rust/src/trace/mod.rs `TraceEvent::canonical_line`).

Nothing is shared with the Rust side except the two specs: the FIFO
continuous-batcher shape (admit up to PREFILL_B admissible waiting
heads when concurrency allows, else decode the first DECODE_MAX_B
running rows one token) and the canonical serialization (fixed key
order, newline-terminated lines folded through FNV-1a 64).  If either
drifts, the digests diverge and this script fails.

Usage:
    python3 python/tests/sim_trace_bench.py [trace-identity.csv]

With no argument, runs the mirror, self-checks the event count, and
prints the digest.  With the CSV produced by
`flashsampling repro trace-identity --out DIR` as argument, additionally
asserts bitwise equality against the Rust-side `sim-mirror` anchor row —
the CI cross-language gate.
"""

import sys

# FNV-1a 64 (rust/src/trace/mod.rs FNV_OFFSET / FNV_PRIME).
FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
MASK64 = (1 << 64) - 1

# Mirror-leg workload + SimReplicaConfig defaults (keep in lockstep with
# trace_identity.rs `mirror_run` and router/sim.rs `SimReplicaConfig`).
NUM_REQUESTS = 6
PREFILL_B = 4
DECODE_MAX_B = 8
MAX_CONCURRENCY = 8


def prompt_len(rid):
    return 24 + (rid % 3) * 8


def max_new(rid):
    return 3 + (rid % 3)


def sim_token(rid, index):
    """router/sim.rs `sim_token`: deterministic model stand-in."""
    return (rid * 31 + (index + 1) * 7) % 2039


class Recorder:
    """Canonical-line serializer + incremental FNV-1a digest.

    Mirrors trace/mod.rs: each event renders as
    `{"seq":N,"step":S,"id":I,"ev":"name",<args in fixed order>}` and
    the digest folds every line plus a trailing newline.
    """

    def __init__(self):
        self.seq = 0
        self.digest = FNV_OFFSET
        self.lines = []

    def emit(self, step, rid, ev, args):
        parts = ['"seq":%d' % self.seq, '"step":%d' % step,
                 '"id":%d' % rid, '"ev":"%s"' % ev]
        for key, val in args:
            if isinstance(val, str):
                parts.append('"%s":"%s"' % (key, val))
            else:
                parts.append('"%s":%d' % (key, val))
        line = "{" + ",".join(parts) + "}"
        self.seq += 1
        self.lines.append(line)
        for byte in line.encode("utf-8") + b"\n":
            self.digest = ((self.digest ^ byte) * FNV_PRIME) & MASK64


def run_mirror():
    """The SimReplica FIFO batcher at Lifecycle level, event-for-event.

    The pool (4096 blocks x 16) is far larger than the live set, so
    admission never blocks and no KV model is needed; with prefix
    caching off there are no radix_attach events, and a bare replica
    emits no dispatch events.
    """
    rec = Recorder()
    clock = 0
    cstep = 0
    waiting = []
    running = []
    for rid in range(NUM_REQUESTS):
        rec.emit(clock, rid, "submit",
                 [("prompt_len", prompt_len(rid)), ("max_new", max_new(rid))])
        waiting.append({"id": rid, "gen": 0})
    while waiting or running:
        clock += 1
        if len(running) < MAX_CONCURRENCY and waiting:
            batch = []
            while (waiting and len(batch) < PREFILL_B
                   and len(running) + len(batch) < MAX_CONCURRENCY):
                batch.append(waiting.pop(0))
            snap = cstep
            cstep += 1
            for row, seq in enumerate(batch):
                rec.emit(clock, seq["id"], "prefill",
                         [("prompt_len", prompt_len(seq["id"]))])
                tok = sim_token(seq["id"], 0)
                seq["gen"] = 1
                rec.emit(clock, seq["id"], "first_token",
                         [("row", row), ("cstep", snap), ("token", tok)])
            for seq in batch:
                if seq["gen"] >= max_new(seq["id"]):
                    rec.emit(clock, seq["id"], "finish",
                             [("reason", "max_tokens"), ("tokens", seq["gen"])])
                else:
                    running.append(seq)
        elif running:
            snap = cstep
            cstep += 1
            for row in range(min(len(running), DECODE_MAX_B)):
                seq = running[row]
                tok = sim_token(seq["id"], seq["gen"])
                seq["gen"] += 1
                rec.emit(clock, seq["id"], "decode_token",
                         [("row", row), ("cstep", snap), ("token", tok)])
            i = 0
            while i < len(running):
                if running[i]["gen"] >= max_new(running[i]["id"]):
                    seq = running.pop(i)
                    rec.emit(clock, seq["id"], "finish",
                             [("reason", "max_tokens"), ("tokens", seq["gen"])])
                else:
                    i += 1
        assert clock < 1000, "mirror livelock"
    return rec


def anchor_from_csv(path):
    """The `sim-mirror,requests,events,digest` row of trace-identity.csv."""
    with open(path) as f:
        for line in f:
            if line.startswith("sim-mirror,"):
                cells = line.strip().split(",")
                return int(cells[2]), int(cells[3], 16)
    raise SystemExit("no sim-mirror row in %s" % path)


def main():
    rec = run_mirror()
    # Lifecycle events only: 6 submits + 6 prefills + 6 first tokens +
    # 6 finishes + one decode_token per remaining token.
    expected = 24 + sum(max_new(rid) - 1 for rid in range(NUM_REQUESTS))
    assert rec.seq == expected, "event count %d != %d" % (rec.seq, expected)
    digest = "0x%016x" % rec.digest
    print("sim_trace_bench: %d events, digest %s" % (rec.seq, digest))
    if len(sys.argv) > 1:
        events, anchor = anchor_from_csv(sys.argv[1])
        assert events == rec.seq, (
            "event count mismatch: rust %d, python %d" % (events, rec.seq))
        assert anchor == rec.digest, (
            "digest mismatch: rust 0x%016x, python %s" % (anchor, digest))
        print("sim_trace_bench: MATCHES the Rust sim-mirror anchor")
    else:
        print("(pass trace-identity.csv to cross-check the Rust anchor)")


if __name__ == "__main__":
    main()
