#!/usr/bin/env python3
"""Cross-language modeled-time profile digest mirror.

Independently reimplements the `profile-mirror` leg of
`repro profile-identity` (rust/src/repro/profile_identity.rs, leg 5):
the trace-identity mirror workload — 6 closed-loop requests,
`prompt_len = 24 + (id % 3) * 8`, `max_new = 3 + (id % 3)`, prefix
cache off, `Lifecycle` trace level — profiled under the pinned
canonical price table (rust/src/profile/mod.rs `PriceTable::canonical`),
and re-derives the canonical integer summary lines plus their FNV-1a 64
digest byte-for-byte (`Profile::canonical_lines` / `Profile::digest`).

Nothing is shared with the Rust side except the specs: the FIFO
continuous-batcher shape (same as sim_trace_bench.py), the window
construction rules (consecutive prefill/first_token events at one step
form one prefill window, decode tokens at one step form one decode
window, front-door events close the open window, finishes stamp at the
enclosing window's end), the integer price table, and the canonical
serialization.  Every quantity is an integer, so there is no float
replay and no tolerance: the digests are equal or the build is wrong.

Usage:
    python3 python/tests/sim_profile_bench.py [profile-identity.csv]

With no argument, runs the mirror, self-checks the conservation laws
(windows tile the makespan; per request, phases + queue == span), and
prints the digest.  With the CSV produced by
`flashsampling repro profile-identity --out DIR` as argument,
additionally asserts the pinned price-table row and bitwise digest
equality against the Rust-side `profile-mirror` anchor row — the CI
cross-language gate.
"""

import sys

# FNV-1a 64 (rust/src/profile/mod.rs FNV_OFFSET / FNV_PRIME).
FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
MASK64 = (1 << 64) - 1

# PriceTable::canonical() — integer microseconds, pinned.  The CSV's
# `price-table` row must carry exactly these values, in this order.
PRICES = {
    "prefill_us_per_token": 15,
    "prefill_stream_floor_us": 2412,
    "window_fixed_us": 1282,
    "decode_step_us": 3805,
    "spec_draft_us": 360,
    "spec_verify_us": 3805,
    "swap_us_per_block": 84,
    "dispatch_us": 24,
}

# Mirror-leg workload + SimReplicaConfig defaults (keep in lockstep with
# trace_identity.rs `mirror_run` and router/sim.rs `SimReplicaConfig`).
NUM_REQUESTS = 6
PREFILL_B = 4
DECODE_MAX_B = 8
MAX_CONCURRENCY = 8


def prompt_len(rid):
    return 24 + (rid % 3) * 8


def max_new(rid):
    return 3 + (rid % 3)


def run_mirror_events():
    """The SimReplica FIFO batcher at Lifecycle level, event-for-event.

    Returns `(step, rid, kind, payload)` tuples — the same stream
    sim_trace_bench.py serializes, kept abstract here because the
    profiler consumes events, not their canonical lines.
    """
    events = []
    clock = 0
    waiting = []
    running = []
    for rid in range(NUM_REQUESTS):
        events.append((clock, rid, "submit", prompt_len(rid)))
        waiting.append({"id": rid, "gen": 0})
    while waiting or running:
        clock += 1
        if len(running) < MAX_CONCURRENCY and waiting:
            batch = []
            while (waiting and len(batch) < PREFILL_B
                   and len(running) + len(batch) < MAX_CONCURRENCY):
                batch.append(waiting.pop(0))
            for seq in batch:
                events.append((clock, seq["id"], "prefill",
                               prompt_len(seq["id"])))
                seq["gen"] = 1
                events.append((clock, seq["id"], "first_token", None))
            for seq in batch:
                if seq["gen"] >= max_new(seq["id"]):
                    events.append((clock, seq["id"], "finish", seq["gen"]))
                else:
                    running.append(seq)
        elif running:
            for row in range(min(len(running), DECODE_MAX_B)):
                seq = running[row]
                seq["gen"] += 1
                events.append((clock, seq["id"], "decode_token", None))
            i = 0
            while i < len(running):
                if running[i]["gen"] >= max_new(running[i]["id"]):
                    seq = running.pop(i)
                    events.append((clock, seq["id"], "finish", seq["gen"]))
                else:
                    i += 1
        assert clock < 1000, "mirror livelock"
    return events


def price_prefill(longest_uncached):
    return max(longest_uncached * PRICES["prefill_us_per_token"],
               PRICES["prefill_stream_floor_us"]) + PRICES["window_fixed_us"]


def profile(events):
    """The window profiler over the mirror event alphabet (submit /
    prefill / first_token / decode_token / finish — no chunk, swap,
    spec, or dispatch events occur on a bare replica with the prefix
    cache off).  Mirrors rust/src/profile/mod.rs `profile_trace`:
    one cursor, windows close on class-or-step change, submits close
    the open window, finishes stamp at the enclosing window's end.
    """
    cursor = 0
    windows = []          # (start, dur, phase, participant ids)
    reqs = {}             # id -> accumulator dict
    open_w = None         # [phase, step, participants, longest, emits, fins]

    def req(rid):
        return reqs.setdefault(rid, {
            "submit": 0, "prefill": 0, "decode": 0, "tokens": 0,
            "ttft": None, "finish": None, "finish_us": None,
        })

    def close():
        nonlocal cursor, open_w
        if open_w is None:
            return
        phase, _step, parts, longest, emits, fins = open_w
        dur = (price_prefill(longest) if phase == "prefill"
               else PRICES["decode_step_us"])
        end = cursor + dur
        for rid in parts:
            req(rid)[phase] += dur
        for rid in emits:
            r = req(rid)
            r["tokens"] += 1
            if r["ttft"] is None:
                r["ttft"] = end
        for rid, toks in fins:
            r = req(rid)
            r["finish"] = "max_tokens"
            r["finish_us"] = end
            assert r["tokens"] == toks, "finish token count drift"
        windows.append((cursor, dur, phase, parts))
        cursor = end
        open_w = None

    for step, rid, kind, payload in events:
        if kind in ("prefill", "first_token", "decode_token"):
            phase = "decode" if kind == "decode_token" else "prefill"
            if open_w is None or open_w[0] != phase or open_w[1] != step:
                close()
                open_w = [phase, step, [], 0, [], []]
            if rid not in open_w[2]:
                open_w[2].append(rid)
            if kind == "prefill":
                # Prefix cache off: the whole prompt is uncached.
                open_w[3] = max(open_w[3], payload)
            else:
                open_w[4].append(rid)
        elif kind == "submit":
            close()
            req(rid)["submit"] = cursor
        elif kind == "finish":
            if open_w is not None:
                open_w[5].append((rid, payload))
            else:
                r = req(rid)
                r["finish"] = "max_tokens"
                r["finish_us"] = cursor
                assert r["tokens"] == payload, "finish token count drift"
        else:
            raise SystemExit("unknown event kind %s" % kind)
    close()
    return reqs, windows, cursor


def canonical_lines(reqs, windows, makespan):
    """`Profile::canonical_lines` for one replica: per-request summary
    rows (id-sorted) plus the replica rollup, fixed key order."""
    lines = []
    for rid in sorted(reqs):
        r = reqs[rid]
        end = r["finish_us"] if r["finish_us"] is not None else makespan
        span = end - r["submit"]
        queue = span - r["prefill"] - r["decode"]
        assert queue >= 0, "request %d: negative queue residual" % rid
        lines.append(
            '{"replica":0,"id":%d,"queue_us":%d,"prefill_us":%d,'
            '"chunk_us":0,"swap_us":0,"spec_us":0,"decode_us":%d,'
            '"span_us":%d,"ttft_us":%d,"tokens":%d,"finish":"%s"}'
            % (rid, queue, r["prefill"], r["decode"], span,
               r["ttft"] if r["ttft"] is not None else 0,
               r["tokens"], r["finish"]))
    lines.append('{"replica":0,"requests":%d,"windows":%d,"makespan_us":%d}'
                 % (len(reqs), len(windows), makespan))
    return lines


def fnv_digest(lines):
    digest = FNV_OFFSET
    for line in lines:
        for byte in line.encode("utf-8") + b"\n":
            digest = ((digest ^ byte) * FNV_PRIME) & MASK64
    return digest


def self_check(reqs, windows, makespan):
    """The conservation laws `ReplicaProfile::check` enforces."""
    at = 0
    for start, dur, _phase, _parts in windows:
        assert start == at, "window gap/overlap at %d" % start
        assert dur >= 0
        at += dur
    assert at == makespan, "windows sum %d != makespan %d" % (at, makespan)
    for rid, r in reqs.items():
        end = r["finish_us"] if r["finish_us"] is not None else makespan
        span = end - r["submit"]
        queue = span - r["prefill"] - r["decode"]
        rescan = sum(
            dur for start, dur, _phase, parts in windows
            if start >= r["submit"] and start + dur <= end
            and rid not in parts)
        assert rescan == queue, (
            "request %d: queue rescan %d != residual %d"
            % (rid, rescan, queue))
        assert r["tokens"] == max_new(rid), "request %d token count" % rid


def anchors_from_csv(path):
    """The `profile-mirror` and `price-table` rows of the report CSV."""
    mirror = None
    table = None
    with open(path) as f:
        for line in f:
            if line.startswith("profile-mirror,"):
                cells = line.strip().split(",")
                mirror = (int(cells[2]), int(cells[3], 16))
            elif line.startswith("price-table,"):
                table = [int(c) for c in line.strip().split(",")[1:]]
    if mirror is None or table is None:
        raise SystemExit("no profile-mirror / price-table rows in %s" % path)
    return mirror, table


def main():
    events = run_mirror_events()
    # Lifecycle events only: 6 submits + 6 prefills + 6 first tokens +
    # 6 finishes + one decode_token per remaining token.
    expected = 24 + sum(max_new(rid) - 1 for rid in range(NUM_REQUESTS))
    assert len(events) == expected, (
        "event count %d != %d" % (len(events), expected))
    reqs, windows, makespan = profile(events)
    self_check(reqs, windows, makespan)
    digest = fnv_digest(canonical_lines(reqs, windows, makespan))
    print("sim_profile_bench: %d events, %d windows, makespan %d us, "
          "digest 0x%016x" % (len(events), len(windows), makespan, digest))
    if len(sys.argv) > 1:
        (events_rs, anchor), table = anchors_from_csv(sys.argv[1])
        assert table == list(PRICES.values()), (
            "price table drift: rust %s, python %s"
            % (table, list(PRICES.values())))
        assert events_rs == len(events), (
            "event count mismatch: rust %d, python %d"
            % (events_rs, len(events)))
        assert anchor == digest, (
            "digest mismatch: rust 0x%016x, python 0x%016x"
            % (anchor, digest))
        print("sim_profile_bench: MATCHES the Rust profile-mirror anchor")
    else:
        print("(pass profile-identity.csv to cross-check the Rust anchor)")


if __name__ == "__main__":
    main()
