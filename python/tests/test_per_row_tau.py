"""Per-row temperature (tau: [B] ABI, manifest v2) — pathwise exactness.

The redesign's kernel-level contract: a batch whose rows carry different
temperatures draws, in one fused launch, exactly the samples each row would
draw alone at its own tau (same Philox positions, per-row transform).  This
is what lets the Rust scheduler coalesce mixed-temperature requests.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import flash_sampling as fs
from compile.kernels import ref as kref

B, D, V = 5, 32, 300  # non-multiples of the tile sizes on purpose
SEED = jnp.asarray([11, 22], jnp.uint32)
TAUS = jnp.asarray([0.5, 0.8, 1.0, 2.0, 4.0], jnp.float32)


@pytest.fixture(scope="module")
def hw():
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(B, D)), jnp.float32) * 0.5
    w = jnp.asarray(rng.normal(size=(V, D)), jnp.float32) * 0.1
    return h, w


def test_scalar_tau_equals_uniform_vector(hw):
    h, w = hw
    a = fs.flash_sample(h, w, SEED, step=3, temperature=0.8, tile_b=2, tile_v=64)
    b = fs.flash_sample(
        h, w, SEED, step=3, temperature=jnp.full((B,), 0.8), tile_b=2, tile_v=64
    )
    assert (a.sample == b.sample).all()


def test_mixed_tau_rows_match_their_solo_draws(hw):
    h, w = hw
    out = fs.flash_sample(h, w, SEED, step=7, temperature=TAUS, tile_b=2, tile_v=64)
    # Monolithic per-row-tau oracle.
    ref_rows = kref.gumbel_max_sample(h, w, SEED, step=7, temperature=TAUS)
    assert (out.sample == ref_rows).all()
    # And each row is pathwise identical to a uniform run at its own tau.
    for r in range(B):
        solo = kref.gumbel_max_sample(h, w, SEED, step=7, temperature=float(TAUS[r]))
        assert int(solo[r]) == int(out.sample[r])


def test_mixed_tau_log_z_is_per_row(hw):
    h, w = hw
    out = fs.flash_sample(
        h, w, SEED, step=7, temperature=TAUS, tile_b=2, tile_v=64, want_log_z=True
    )
    y = kref.logits(h, w, temperature=TAUS)
    lz = jnp.log(jnp.sum(jnp.exp(y - y.max(1, keepdims=True)), 1)) + y.max(1)
    assert np.allclose(out.log_z, lz, atol=1e-3)


def test_shard_merge_with_mixed_tau_is_pathwise_exact(hw):
    h, w = hw
    n = 2
    vs = V // n
    w_even = w[: vs * n]
    full = fs.flash_sample(h, w_even, SEED, step=5, temperature=TAUS, tile_b=2, tile_v=64)
    ms, idxs = [], []
    for r in range(n):
        m, local, _ = fs.shard_candidates(
            h, w_even[r * vs : (r + 1) * vs], r * vs, SEED, step=5,
            temperature=TAUS, tile_b=2, tile_v=64,
        )
        ms.append(m)
        idxs.append(local)
    ms = jnp.stack(ms, 1)
    idxs = jnp.stack(idxs, 1)
    r_star = jnp.argmax(ms, 1)
    merged = jnp.take_along_axis(idxs, r_star[:, None], 1)[:, 0]
    assert (merged == full.sample).all()


def test_baseline_multinomial_accepts_per_row_tau(hw):
    h, w = hw
    s = kref.multinomial_sample(h, w, SEED, step=2, temperature=TAUS)
    assert s.shape == (B,)
    assert (s >= 0).all() and (s < V).all()
    # Scalar path unchanged (broadcasting, not a signature fork).
    s1 = kref.multinomial_sample(h, w, SEED, step=2, temperature=1.0)
    assert s1.shape == (B,)
