"""Offline accounting simulation of `cargo bench --bench router`.

Reproduces, bit-for-bit, the DETERMINISTIC fields of the bench's
`BENCH_router.json` records: the closed-loop drive of the multi-replica
`Router` over `SimReplica` backends (`rust/src/router/sim.rs`), in the
bench's regime — a KV pool far larger than the live set (prefix-cache
eviction never engages, free blocks are the exact probe headroom), no
aborts, no swaps.  In that regime every routing decision is a pure
function of the replica probes (`pick_replica` in
`rust/src/router/policy.rs`, ported verbatim below, including the
FNV-1a chain hash that seeds cold-start prefix affinity) and every
replica schedule is the FIFO continuous-batching mirror with the
token-weighted cost model (a prefill batch costs its longest uncached
suffix, a decode step costs 1).  This file therefore reimplements, in
lockstep with the Rust source:

  * the radix prefix cache as full-block chain lookups plus the
    allocator refcounts that drive `free_blocks()` (the least-loaded
    tiebreak) — `Kv` below mirrors `kvcache::KvCacheManager`;
  * `SimReplica.step()` — admission, batch cost, decode sweep, and the
    weighted submit→completion latency each record's percentiles are
    computed from;
  * `Router.submit()` — probe, home hash, policy pick, and the
    round-robin cursor that advances only on accepted submissions.

Token VALUES are irrelevant to every recorded field, so the sim-token
formula is not mirrored (only counts and weighted times are).

Timing fields (`median_ns` etc.) are bench-only: running `cargo bench
--bench router` on a toolbox overwrites this snapshot with `source:
"bench"` records that add them (the shared fields must not change — if
they do, the mirror or the Rust code regressed).

Usage:  cd python && python tests/sim_router_bench.py [out.json]
"""

import json
import struct
import sys
from collections import deque

SESSIONS = 12
TURNS = 4
REQUESTS = SESSIONS * TURNS
NUM_SYS = 6
MAX_NEW = 4

BLOCK_SIZE = 16
NUM_BLOCKS = 4096
MAX_CONCURRENCY = 8
PREFILL_B = 4
DECODE_MAX_B = 8

# policy.rs: pending-count slack before affinity spills to least-loaded.
SPILL_PENDING_MARGIN = 4

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
U64 = (1 << 64) - 1


def fnv(h, data):
    for b in data:
        h ^= b
        h = (h * FNV_PRIME) & U64
    return h


def prefix_home_hash(prompt):
    """prefixcache::prefix_home_hash — the chain hash of the prompt's
    first full block (parent = the ROOT_HASH sentinel = FNV_OFFSET)."""
    if len(prompt) < BLOCK_SIZE:
        return None
    h = fnv(FNV_OFFSET, FNV_OFFSET.to_bytes(8, "little"))
    return fnv(
        h, b"".join(struct.pack("<i", t) for t in prompt[:BLOCK_SIZE])
    )


def session_prompt(session, turn):
    sys_id = session % NUM_SYS
    p = [(sys_id * 97 + j * 13 + 5) % 2048 for j in range(32)]
    for t in range(turn + 1):
        p.extend(
            (session * 59 + t * 31 + j * 7 + 11) % 2048 for j in range(16)
        )
    return p


class Kv:
    """Refcount mirror of `kvcache::KvCacheManager` in the bench regime.

    The radix tree reduces to full-block chain-prefix lookups (every
    insert publishes a contiguous chain from the root, so presence of a
    length-k chain implies all its prefixes); blocks are refcounted ids
    whose only observable is the free-block count the probes report."""

    def __init__(self):
        self.free = NUM_BLOCKS
        self.cache = {}  # chain prefix (tuple of tokens) -> block id
        self.ref = {}  # block id -> refcount
        self.tables = {}  # seq id -> [block ids]
        self.lens = {}  # seq id -> logical token length
        self.next_block = 0

    def _alloc(self):
        assert self.free > 0, "pool sized so exhaustion is unreachable"
        self.free -= 1
        b = self.next_block
        self.next_block += 1
        self.ref[b] = 1
        return b

    def cached_prefix_tokens(self, prompt):
        # Capped below the prompt length: prefill keeps >= 1 suffix token.
        cap = (len(prompt) - 1) // BLOCK_SIZE
        k = 0
        while k < cap and tuple(prompt[: (k + 1) * BLOCK_SIZE]) in self.cache:
            k += 1
        return k * BLOCK_SIZE

    def prefill_blocks_needed(self, prompt):
        matched = self.cached_prefix_tokens(prompt) // BLOCK_SIZE
        return -(-len(prompt) // BLOCK_SIZE) - matched

    def can_allocate_prefill(self, prompt):
        # prefill_headroom = free + evictable - matched >= free in the
        # no-eviction regime; free alone is exact here.
        return self.free >= self.prefill_blocks_needed(prompt)

    def register_with_prefix(self, seq, prompt):
        matched_tokens = self.cached_prefix_tokens(prompt)
        table = []
        for k in range(1, matched_tokens // BLOCK_SIZE + 1):
            b = self.cache[tuple(prompt[: k * BLOCK_SIZE])]
            self.ref[b] += 1  # copy-on-write attach
            table.append(b)
        for _ in range(self.prefill_blocks_needed(prompt)):
            table.append(self._alloc())
        self.tables[seq] = table
        self.lens[seq] = len(prompt)
        return matched_tokens

    def insert_prefix(self, seq, prompt):
        # Publish the prompt's full blocks; the cache takes one ref per
        # newly inserted block (already-cached chains are left alone).
        for j in range(len(prompt) // BLOCK_SIZE):
            key = tuple(prompt[: (j + 1) * BLOCK_SIZE])
            if key not in self.cache:
                b = self.tables[seq][j]
                self.cache[key] = b
                self.ref[b] += 1

    def append_token(self, seq):
        table, length = self.tables[seq], self.lens[seq]
        if length == len(table) * BLOCK_SIZE:
            table.append(self._alloc())  # block boundary
        elif self.ref[table[-1]] > 1:
            # Copy-on-write into a shared tail — unreachable in this
            # workload (prompts are block-aligned, so the decode tail is
            # always private), mirrored for allocator lockstep anyway.
            old = table.pop()
            self.ref[old] -= 1
            table.append(self._alloc())
        self.lens[seq] = length + 1

    def release(self, seq):
        for b in self.tables.pop(seq):
            self.ref[b] -= 1
            if self.ref[b] == 0:  # cache-held blocks keep their ref
                del self.ref[b]
                self.free += 1
        del self.lens[seq]


class Seq:
    __slots__ = ("id", "prompt", "generated", "submit_w")

    def __init__(self, rid, prompt, submit_w):
        self.id = rid
        self.prompt = prompt
        self.generated = 0
        self.submit_w = submit_w


class SimReplica:
    """FIFO continuous-batching mirror of `router::sim::SimReplica`."""

    def __init__(self):
        self.kv = Kv()
        self.waiting = deque()
        self.running = []
        self.wtime = 0
        self.prefill_tokens = 0
        self.cached_prefill_tokens = 0
        self.completions = []  # (id, weighted submit->completion latency)

    def submit(self, rid, prompt):
        self.waiting.append(Seq(rid, prompt, self.wtime))

    def pending(self):
        return len(self.waiting) + len(self.running)

    def _complete(self, s):
        self.kv.release(s.id)
        self.completions.append((s.id, self.wtime - s.submit_w))

    def step(self):
        can_prefill = (
            len(self.running) < MAX_CONCURRENCY
            and self.waiting
            and self.kv.can_allocate_prefill(self.waiting[0].prompt)
        )
        progressed = False
        if can_prefill:
            batch = []
            while (
                len(batch) < PREFILL_B
                and len(self.running) + len(batch) < MAX_CONCURRENCY
                and self.waiting
                and self.kv.can_allocate_prefill(self.waiting[0].prompt)
            ):
                batch.append(self.waiting.popleft())
            cost = 1
            for s in batch:
                cached = self.kv.register_with_prefix(s.id, s.prompt)
                self.prefill_tokens += len(s.prompt)
                self.cached_prefill_tokens += cached
                cost = max(cost, len(s.prompt) - cached)
                self.kv.insert_prefix(s.id, s.prompt)
                s.generated = 1  # first token samples at prefill
            self.wtime += cost
            for s in batch:
                if s.generated >= MAX_NEW:
                    self._complete(s)
                else:
                    self.running.append(s)
            progressed = True
        elif self.running:
            self.wtime += 1
            for s in self.running[: min(len(self.running), DECODE_MAX_B)]:
                self.kv.append_token(s.id)
                s.generated += 1
            retired = [s for s in self.running if s.generated >= MAX_NEW]
            for s in retired:
                self.running.remove(s)
                self._complete(s)
            progressed = True
        return progressed


def least_loaded(probes):
    best = 0
    for i in range(1, len(probes)):
        p, b = probes[i], probes[best]
        if (p[0], -p[1]) < (b[0], -b[1]):  # (pending, Reverse(headroom))
            best = i
    return best


def pick_replica(policy, rr_next, probes, home):
    """Verbatim port of `router::policy::pick_replica`.  A probe is the
    tuple (pending, headroom, blocks_needed, cached_tokens)."""
    n = len(probes)
    if policy == "round-robin":
        return rr_next % n
    if policy == "least-loaded":
        return least_loaded(probes)
    warm = [i for i in range(n) if probes[i][3] > 0]
    if warm:
        chosen = min(warm, key=lambda i: (-probes[i][3], probes[i][0], i))
    elif home is not None:
        chosen = home % n
    else:
        return least_loaded(probes)
    pending, headroom, needed, _ = probes[chosen]
    min_pending = min(p[0] for p in probes)
    if headroom < needed or pending > min_pending + SPILL_PENDING_MARGIN:
        return least_loaded(probes)
    return chosen


def drive(n, policy):
    reps = [SimReplica() for _ in range(n)]
    rr_next = 0
    for turn in range(TURNS):
        # Rotated submission order (arrival jitter): session (turn + k) %
        # SESSIONS arrives k-th.  Without it, least-loaded's position-based
        # alternation is accidentally session-stable across drained waves
        # and ties affinity on cache reuse; with it, sessions flip replicas
        # under least-loaded while affinity follows the warm cache.
        for k in range(SESSIONS):
            session = (turn + k) % SESSIONS
            rid = turn * SESSIONS + session
            prompt = session_prompt(session, turn)
            probes = [
                (
                    r.pending(),
                    r.kv.free,
                    r.kv.prefill_blocks_needed(prompt),
                    r.kv.cached_prefix_tokens(prompt),
                )
                for r in reps
            ]
            idx = pick_replica(policy, rr_next, probes, prefix_home_hash(prompt))
            reps[idx].submit(rid, prompt)
            rr_next += 1
        idle = 0
        while any(r.pending() for r in reps):
            progressed = False
            for r in reps:
                progressed |= r.step()
            idle = 0 if progressed else idle + 1
            assert idle < 64, "router mirror livelock"
    return reps


def pct(sorted_vals, q):
    return sorted_vals[min(int(len(sorted_vals) * q), len(sorted_vals) - 1)]


def record(n, policy):
    reps = drive(n, policy)
    latency = [c for r in reps for c in r.completions]
    assert len(latency) == REQUESTS, f"r{n}/{policy}: dropped requests"
    lat = sorted(w for _, w in latency)
    warm = sorted(w for rid, w in latency if rid >= SESSIONS)
    per_replica = [len(r.completions) for r in reps]
    return {
        "scenario": policy,
        "source": "accounting-sim",
        "replicas": n,
        "requests": REQUESTS,
        "completed": len(latency),
        "prefill_tokens": sum(r.prefill_tokens for r in reps),
        "cached_prefill_tokens": sum(r.cached_prefill_tokens for r in reps),
        "latency_p50_w": pct(lat, 0.5),
        "latency_p95_w": pct(lat, 0.95),
        "warm_latency_p95_w": pct(warm, 0.95),
        "makespan_w": max(r.wtime for r in reps),
        "tokens_generated": REQUESTS * MAX_NEW,
        "min_replica_completed": min(per_replica),
    }


def main():
    records = []
    for n in (1, 2, 4):
        by_policy = []
        for policy in ("round-robin", "least-loaded", "prefix-affinity"):
            r = record(n, policy)
            by_policy.append(r)
            records.append(r)
            print(
                f"replicas {n} {policy:<16} "
                f"lat p50/p95 {r['latency_p50_w']:>4}/{r['latency_p95_w']:>4} | "
                f"warm p95 {r['warm_latency_p95_w']:>4} | "
                f"cached/prefill {r['cached_prefill_tokens']:>5}/"
                f"{r['prefill_tokens']:>5} | "
                f"makespan {r['makespan_w']:>4} | "
                f"min-replica {r['min_replica_completed']}"
            )
        # The bench's acceptance bars, checked here too.
        assert all(
            r["prefill_tokens"] == by_policy[0]["prefill_tokens"]
            for r in by_policy
        ), f"replicas {n}: prefill totals diverged"
        if n >= 2:
            aff, ll = by_policy[2], by_policy[1]
            assert (
                aff["cached_prefill_tokens"] > ll["cached_prefill_tokens"]
            ), f"replicas {n}: affinity did not beat least-loaded"
            assert aff["min_replica_completed"] > 0, (
                f"replicas {n}: prefix affinity starved a replica"
            )

    out = sys.argv[1] if len(sys.argv) > 1 else "BENCH_router.json"
    body = ",\n".join(
        "    " + json.dumps(r, separators=(", ", ": ")) for r in records
    )
    config = json.dumps(
        {"sessions": SESSIONS, "turns": TURNS, "num_sys": NUM_SYS, "max_new": MAX_NEW},
        separators=(", ", ": "),
    )
    text = (
        '{\n  "bench": "router",\n  "schema_version": 2,\n'
        '  "source": "accounting-sim",\n'
        '  "config": ' + config + ",\n"
        '  "results": [\n' + body + "\n  ]\n}\n"
    )
    with open(out, "w") as f:
        f.write(text)
    print(f"\nwrote {out} ({len(records)} records)")


if __name__ == "__main__":
    main()
