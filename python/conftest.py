"""pytest configuration: make `compile.*` importable when running from the
python/ directory (the Makefile does `cd python && pytest tests/ -q`)."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
